"""Per-request trace spans: the full lifecycle of every rid, exportable.

A *span* is the ordered event list of one request id. Events are flat
dicts ``{"rid", "event", "t", **fields}`` — ``t`` comes from the
injected clock (the scheduler's own, so DES benches get simulated
timestamps and fake-clock tests stay deterministic). The schedulers emit:

========== ============================================================
event      meaning / fields
========== ============================================================
submit     request accepted by ``submit``/``submit_points``; ``M``,
           ``N``, ``bucket``, ``kind`` ('dense'|'points'), ``deadline``,
           ``priority``
queue      entered the admission (or gang) queue; ``depth``, ``route``
shed       deadline-shed decision at admission; ``policy``
place      got a lane; ``device`` (-1 single-device), ``lane``,
           ``bucket`` (the *pool's* — wider when pool-shared), ``route``
chunk      observed between chunk advances while in a lane; ``lane``,
           ``device``, ``iters``, ``converged``, ``healthy``
evict      left its lane; ``lane``, ``device``, ``iters``,
           ``converged``, ``healthy``
requeue    cluster drain/poison bounce back into the queue; ``retries``
escalate   log-domain retry of a quarantined request; ``retries``
gang       solved on the gang tier; ``devices``, ``iters``
complete   TERMINAL — exactly one per rid; ``status`` in ok /
           retried_ok / timed_out / failed / rejected (+ ``iters``,
           ``reason`` where meaningful)
lost       the *coupling* fell off the bounded result store after
           completion (poll now resolves to a 'lost' failure); the
           complete event stays the terminal span record
poll       client collected the rid; ``resolved``
           ('coupling'|'failure'|'pending')
========== ============================================================

The zero-span-loss invariant (asserted by ``bench_serve`` /
``bench_chaos`` and the chaos CI job) is ``check_complete()``: every
submitted rid carries exactly one ``complete`` event. ``terminal_status``
folds a later ``lost`` marker in, matching what ``poll`` would return.

Control-plane events use **negative rids**: the SLO monitor
(``repro.obs.slo``) emits ``alert`` transitions under rid ``-1``. They
carry no request lifecycle, so ``rids()`` and ``check_complete`` skip
negative rids — an alert never shows up as a lost span. ``span(-1)``
still returns them for inspection.

Export is JSONL (one event per line, ``write_jsonl``/``load_jsonl``
round-trip exactly) and ``render_timeline`` draws a text timeline for
humans. ``NullTracer`` is the disabled twin: same surface, ``emit`` is a
no-op — the obs-overhead CI job measures on-vs-off with it.
"""
from __future__ import annotations

import json
import time
from typing import Callable, Iterable

TERMINAL_STATUSES = ("ok", "retried_ok", "timed_out", "failed", "rejected",
                     "lost")


class SpanTracer:
    """Append-only per-request event recorder (see module docstring)."""

    enabled = True

    def __init__(self, clock: Callable[[], float] = time.monotonic):
        self.clock = clock
        self.events: list[dict] = []

    def emit(self, rid: int, event: str, **fields) -> None:
        e = {"rid": rid, "event": event, "t": self.clock()}
        e.update(fields)
        self.events.append(e)

    def clear(self) -> None:
        self.events.clear()

    # ---- span queries -----------------------------------------------------

    def rids(self) -> list[int]:
        """Every *request* rid that emitted at least one event, in
        first-seen order. Negative rids are control-plane events (SLO
        alerts) and are excluded — use ``span(-1)`` to read them."""
        seen: dict[int, None] = {}
        for e in self.events:
            if e["rid"] >= 0:
                seen.setdefault(e["rid"], None)
        return list(seen)

    def span(self, rid: int) -> list[dict]:
        return [e for e in self.events if e["rid"] == rid]

    def terminal_status(self, rid: int) -> str | None:
        """What ``poll`` resolves this rid to: the ``complete`` status,
        overridden by 'lost' when the coupling later fell off the result
        store; None while the request is still pending."""
        status = None
        for e in self.events:
            if e["rid"] != rid:
                continue
            if e["event"] == "complete":
                status = e["status"]
            elif e["event"] == "lost":
                status = "lost"
        return status

    def check_complete(self, submitted=None) -> dict:
        """The zero-span-loss audit. Returns ``{'total', 'missing',
        'multiple'}`` — rids with no / more-than-one terminal ``complete``
        event. ``submitted`` (iterable of rids) widens the audited set
        beyond the rids that emitted events (a rid with NO events at all
        is a lost span too). An empty ``missing`` + ``multiple`` is the
        invariant benches and the chaos CI job assert."""
        counts: dict[int, int] = {}
        for rid in self.rids():
            counts[rid] = 0
        if submitted is not None:
            for rid in submitted:
                counts.setdefault(rid, 0)
        for e in self.events:
            if e["event"] == "complete":
                counts[e["rid"]] = counts.get(e["rid"], 0) + 1
        return {
            "total": len(counts),
            "missing": sorted(r for r, c in counts.items() if c == 0),
            "multiple": sorted(r for r, c in counts.items() if c > 1),
        }

    # ---- export -----------------------------------------------------------

    def write_jsonl(self, path) -> int:
        """One event per line; returns the number of lines written."""
        with open(path, "w") as f:
            for e in self.events:
                f.write(json.dumps(e) + "\n")
        return len(self.events)

    @staticmethod
    def load_jsonl(path) -> list[dict]:
        out = []
        with open(path) as f:
            for line in f:
                line = line.strip()
                if line:
                    out.append(json.loads(line))
        return out

    @classmethod
    def from_events(cls, events: Iterable[dict],
                    clock: Callable[[], float] = time.monotonic):
        """Rebuild a tracer around exported events (JSONL reload)."""
        tr = cls(clock=clock)
        tr.events = list(events)
        return tr

    # ---- human rendering --------------------------------------------------

    def render_timeline(self, rids=None, width: int = 60) -> str:
        """Text timeline: one row per rid, event initials placed
        proportionally between the trace's first and last timestamp,
        terminal status at the right edge. For eyeballs, not parsers —
        the JSONL export is the machine surface."""
        if not self.events:
            return "(no events)"
        rids = list(rids) if rids is not None else self.rids()
        t0 = min(e["t"] for e in self.events)
        t1 = max(e["t"] for e in self.events)
        dt = (t1 - t0) or 1.0
        initials = {"submit": "S", "queue": "q", "shed": "x", "place": "P",
                    "chunk": ".", "evict": "E", "requeue": "r",
                    "escalate": "!", "gang": "G", "complete": "C",
                    "lost": "L", "poll": "p"}
        lines = [f"t0={t0:.6f}  span={dt:.6f}s  "
                 f"({len(self.events)} events, {len(rids)} rids)"]
        for rid in rids:
            row = [" "] * width
            status = None
            for e in self.span(rid):
                pos = min(width - 1, int((e["t"] - t0) / dt * (width - 1)))
                row[pos] = initials.get(e["event"], "?")
                if e["event"] == "complete":
                    status = e["status"]
                elif e["event"] == "lost":
                    status = "lost"
            lines.append(f"rid {rid:>6} |{''.join(row)}| "
                         f"{status or 'pending'}")
        return "\n".join(lines)


class NullTracer:
    """Disabled tracer: same surface as ``SpanTracer``, ``emit`` drops the
    event. ``events`` stays an empty tuple so accidental iteration is
    harmless and zero-cost."""

    enabled = False
    events: tuple = ()

    def __init__(self, clock: Callable[[], float] = time.monotonic):
        self.clock = clock

    def emit(self, rid: int, event: str, **fields) -> None:
        pass

    def clear(self) -> None:
        pass

    def rids(self) -> list:
        return []

    def span(self, rid: int) -> list:
        return []

    def terminal_status(self, rid: int):
        return None

    def check_complete(self, submitted=None) -> dict:
        return {"total": 0, "missing": [], "multiple": []}

    def write_jsonl(self, path) -> int:
        with open(path, "w"):
            pass
        return 0

    load_jsonl = staticmethod(SpanTracer.load_jsonl)

    def render_timeline(self, rids=None, width: int = 60) -> str:
        return "(tracing disabled)"
