"""Wall-clock profiler: scheduler round phases + kernel-launch timing.

The measured half of the observability story. ``repro.obs.traffic``
charges *modeled* bytes per dispatch decision; this module records the
*measured* host wall-clock next to them, so achieved GB/s per kernel
cell (``repro.obs.measure``) and an honest roofline fraction can sit
beside the modeled ones.

Two instruments, both feeding ``profile.*`` registry histograms:

* ``PhaseTimer`` — scoped timers for the scheduler round phases
  (admission prep / device chunk / eviction / poll, in both the
  ``serve`` and ``cluster`` step loops). Phases nest: each phase records
  its **total** wall time under ``profile.phase.<name>`` and its
  **exclusive** time (total minus enclosed child phases) under
  ``profile.phase.<name>.self``, so a round's breakdown sums correctly
  even when one phase wraps another.
* ``KernelProfiler`` — per-launch timing of every dispatched solve /
  chunk, keyed by the **measurement cell**
  ``(kernel, MxN shape, storage itemsize, impl tier, cost source,
  lanes, iteration budget)`` — the same parameters the traffic
  accountant's formulas take, so a cell's measured seconds divide its
  modeled bytes with no joins. The *first* observation of a cell is the
  trace+compile call and is recorded separately
  (``profile.compile.<cell>``) from steady-state execute
  (``profile.kernel.<cell>``); steady-state samples are additionally
  kept in a small bounded deque for exact medians (histograms give
  bucket-interpolated percentiles only). ``kernels/ops.py`` installs
  the hook via ``ops.launch_profiler(profiler)`` — the launch-timing
  twin of ``ops.dispatch_observer`` — and forces a device sync per
  profiled launch, which is why the null twins exist: under
  ``obs=False`` nothing is installed and no sync happens.

Clocks: phase/launch timing uses ``time.perf_counter`` by default even
when the owning scheduler runs on a simulated clock — kernel cost is a
host wall-clock fact, not a DES fact. Tests inject a fake ``clock=``.
"""
from __future__ import annotations

import collections
import contextlib
import threading
import time
from typing import Callable

__all__ = ["PhaseTimer", "NullPhaseTimer", "KernelProfiler",
           "NullKernelProfiler", "cell_key", "parse_cell_key"]


def cell_key(kernel: str, M: int, N: int, itemsize: int, impl: str,
             source: str = "dense", lanes: int = 1, iters: int = 1) -> str:
    """Canonical string key of one measurement cell (JSON-able, stable)."""
    return (f"{kernel}|{M}x{N}|s{itemsize}|{impl}|{source}"
            f"|L{lanes}|T{iters}")


def parse_cell_key(key: str) -> dict:
    """Inverse of ``cell_key`` — the formula parameters as a dict."""
    kernel, shape, s, impl, source, lanes, iters = key.split("|")
    M, N = shape.split("x")
    return {"kernel": kernel, "M": int(M), "N": int(N),
            "itemsize": int(s[1:]), "impl": impl, "source": source,
            "lanes": int(lanes[1:]), "iters": int(iters[1:])}


class PhaseTimer:
    """Scoped wall-clock timers for named phases, nesting-aware.

    ``with phases.phase("serve.chunk"): ...`` observes the elapsed
    seconds into ``profile.phase.serve.chunk`` and the exclusive
    (children-subtracted) seconds into ``...serve.chunk.self``. The
    phase stack is thread-local: concurrent step loops in different
    threads do not see each other's frames.
    """

    enabled = True

    def __init__(self, registry, *, prefix: str = "profile.phase",
                 clock: Callable[[], float] = time.perf_counter):
        self.registry = registry
        self.prefix = prefix
        self.clock = clock
        self._local = threading.local()

    def _stack(self) -> list:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    @contextlib.contextmanager
    def phase(self, name: str):
        stack = self._stack()
        frame = [self.clock(), 0.0]   # [start, accumulated child total]
        stack.append(frame)
        try:
            yield
        finally:
            stack.pop()
            total = self.clock() - frame[0]
            if stack:
                stack[-1][1] += total
            self.registry.histogram(f"{self.prefix}.{name}").observe(total)
            self.registry.histogram(
                f"{self.prefix}.{name}.self").observe(total - frame[1])


class NullPhaseTimer:
    """``obs=False`` twin: ``phase()`` is a free nullcontext."""

    enabled = False

    def __init__(self, *_, **__):
        pass

    def phase(self, name: str):
        return contextlib.nullcontext()


class _Cell:
    __slots__ = ("count", "first_s", "samples")

    def __init__(self, keep: int):
        self.count = 0
        self.first_s: float | None = None
        self.samples: collections.deque = collections.deque(maxlen=keep)


class KernelProfiler:
    """Per-cell launch timing: first-call apart from steady-state.

    ``observe_launch`` is the sink ``ops.launch_profiler`` feeds (ops
    does the ``block_until_ready`` timing; this object only ingests
    seconds). The first observation of a cell is the trace+compile call
    — its time goes to ``profile.compile.<cell>`` and is excluded from
    the steady-state deque, so ``median_us`` never includes compile.
    """

    enabled = True

    def __init__(self, registry=None, *, keep: int = 128, parent=None):
        self.registry = registry
        self.keep = keep
        self.parent = parent
        self._lock = threading.Lock()
        self._cells: dict[str, _Cell] = {}

    def _record(self, key: str, seconds: float) -> bool:
        """Cell bookkeeping only; returns whether this was the cell's
        first (trace+compile) observation."""
        with self._lock:
            cell = self._cells.get(key)
            first = cell is None
            if first:
                cell = self._cells[key] = _Cell(self.keep)
                cell.first_s = float(seconds)
            else:
                cell.samples.append(float(seconds))
            cell.count += 1
        return first

    def observe_launch(self, *, kernel: str, M: int, N: int, itemsize: int,
                       impl: str, source: str = "dense", lanes: int = 1,
                       iters: int = 1, seconds: float) -> None:
        key = cell_key(kernel, M, N, itemsize, impl, source, lanes, iters)
        first = self._record(key, seconds)
        if self.registry is not None:
            name = ("profile.compile." if first else "profile.kernel.")
            self.registry.histogram(name + key).observe(seconds)
        # parent chain mirrors the registry's rollup, cells-only: the
        # histogram observation above already propagates through the
        # parent-chained registry, so ancestors get _record alone
        p = self.parent
        while p is not None:
            p._record(key, seconds)
            p = getattr(p, "parent", None)

    # -- readback ---------------------------------------------------------
    @staticmethod
    def _median(samples) -> float | None:
        if not samples:
            return None
        s = sorted(samples)
        n = len(s)
        mid = n // 2
        return s[mid] if n % 2 else 0.5 * (s[mid - 1] + s[mid])

    def median_us(self, key: str) -> float | None:
        """Exact steady-state median us/call for a cell (None until the
        cell has a post-compile sample)."""
        with self._lock:
            cell = self._cells.get(key)
            med = self._median(cell.samples) if cell is not None else None
        return med * 1e6 if med is not None else None

    def cells(self) -> dict[str, dict]:
        """JSON-able snapshot: ``{cell_key: {count, median_us, first_us}}``
        — the payload ``MeasurementStore.ingest`` persists."""
        out = {}
        with self._lock:
            items = [(k, c.count, c.first_s, self._median(c.samples))
                     for k, c in self._cells.items()]
        for key, count, first_s, med in items:
            out[key] = {
                "count": count,
                "median_us": med * 1e6 if med is not None else None,
                "first_us": first_s * 1e6 if first_s is not None else None,
            }
        return out

    def dump(self) -> dict:
        return {"enabled": True, "cells": self.cells()}

    def reset(self) -> None:
        with self._lock:
            self._cells.clear()


class NullKernelProfiler:
    """``obs=False`` twin: never installed by ``ops.launch_profiler``
    (``enabled`` is False), so no launch is ever synced or timed."""

    enabled = False

    def __init__(self, *_, **__):
        pass

    def observe_launch(self, **_) -> None:
        pass

    def median_us(self, key: str) -> None:
        return None

    def cells(self) -> dict:
        return {}

    def dump(self) -> dict:
        return {"enabled": False, "cells": {}}

    def reset(self) -> None:
        pass
