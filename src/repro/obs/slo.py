"""Declarative SLO objectives and multi-window burn-rate alerting.

An ``SLO(name, objective, window, series)`` binds a bound (``objective``,
the value the series must stay under) to a windowed series read from a
``WindowedAggregator`` (``repro.obs.windows``). ``SLOMonitor.evaluate()``
— called once per scheduling round by both schedulers — reads each SLO's
series over a **fast/slow window pair** and converts the values to burn
rates (``value / objective``; for ratio SLOs this is the classic
error-budget burn multiple):

* the SLO *fires* only when the burn rate is at or above ``burn`` in
  BOTH windows — the slow window proves the breach is sustained, the
  fast window proves it is still happening (the standard multi-window
  burn-rate rule, so a long-resolved incident can't keep an alert up);
* it *resolves* when the fast burn falls below ``clear_ratio * burn``;
* both transitions require ``patience`` consecutive evaluations — the
  same two-watermark + patience hysteresis as the overload ladder's
  ``BrownoutController`` (``repro.serve.overload``), so one noisy round
  neither raises nor clears an alert.

Series over a window (``Series`` implementations below):

=================  =====================================================
``CounterRatio``   bad/total counter-delta fraction (deadline-miss
                   rate, degrade fraction). ``value`` is None when the
                   denominator's windowed delta is 0 — no data, no burn.
``CounterDelta``   raw windowed counter delta (device quarantines, gang
                   timeouts: objective 0.5 fires on the first event).
``CounterRate``    windowed events/second.
``HistPercentile`` windowed interpolated percentile (p99 latency).
``GaugeSeries``    last-set gauge value (brownout level, queue depth).
``Drift``          any zero-arg callable — e.g. the measured-vs-modeled
                   roofline drift from ``repro.obs.measure`` via
                   ``roofline_drift(store)``.
=================  =====================================================

``Alert`` is the typed transition event. Each one is appended to the
monitor's bounded ``alerts`` history, counted in the registry
(``slo.alerts.firing`` / ``slo.alerts.resolved``), mirrored into gauges
(``slo.<name>.burn``, ``slo.<name>.firing``), emitted through the span
tracer as an ``alert`` event under the control-plane rid ``-1`` (see
``repro.obs.trace`` — excluded from the zero-span-loss audit), and fed
to ``on_alert`` callbacks — the schedulers hook the flight recorder's
``dump`` there, so a firing alert freezes the black box.

``NullSLOMonitor`` is the ``obs=False`` twin: no SLOs, ``evaluate`` is
free, never an alert.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable

__all__ = ["SLO", "Alert", "SLOMonitor", "NullSLOMonitor",
           "Series", "CounterRatio", "CounterDelta", "CounterRate",
           "HistPercentile", "GaugeSeries", "Drift", "roofline_drift",
           "default_slos"]


class Series:
    """A windowed scalar. ``value(view)`` returns the reading or None
    (no data — treated as zero burn); ``count(view)`` is the population
    the reading is based on, gating ``SLO.min_count``."""

    def value(self, view) -> float | None:
        raise NotImplementedError

    def count(self, view) -> float:
        return float("inf")


@dataclasses.dataclass(frozen=True)
class CounterRatio(Series):
    """bad/total windowed counter-delta fraction."""

    bad: str
    total: str

    def value(self, view) -> float | None:
        tot = view.counter_delta(self.total)
        if tot <= 0:
            return None
        return view.counter_delta(self.bad) / tot

    def count(self, view) -> float:
        return float(view.counter_delta(self.total))


@dataclasses.dataclass(frozen=True)
class CounterDelta(Series):
    name: str

    def value(self, view) -> float | None:
        return float(view.counter_delta(self.name))

    def count(self, view) -> float:
        return float(view.counter_delta(self.name))


@dataclasses.dataclass(frozen=True)
class CounterRate(Series):
    name: str

    def value(self, view) -> float | None:
        return view.rate(self.name)


@dataclasses.dataclass(frozen=True)
class HistPercentile(Series):
    name: str
    q: float = 99.0

    def value(self, view) -> float | None:
        if view.hist_count(self.name) <= 0:
            return None
        return view.percentile(self.name, self.q)

    def count(self, view) -> float:
        return float(view.hist_count(self.name))


@dataclasses.dataclass(frozen=True)
class GaugeSeries(Series):
    name: str

    def value(self, view) -> float | None:
        return view.gauge(self.name)


@dataclasses.dataclass(frozen=True)
class Drift(Series):
    """Window-independent external reading (both windows see the same
    value, so the multi-window rule degenerates to a plain threshold
    with hysteresis — appropriate for slowly-refreshed sources)."""

    fn: Callable[[], float | None]

    def value(self, view) -> float | None:
        return self.fn()


def roofline_drift(store, *, q: float = 0.5) -> Drift:
    """Measured-vs-modeled roofline drift from a
    ``measure.MeasurementStore``: the median (by default) over cells of
    ``|1 - measured_roofline_fraction|`` — 0.0 when measured bandwidth
    matches the modeled datasheet roofline, growing toward 1.0 as the
    machine drifts from the model. Returns None (no burn) until the
    store has cells with achieved bandwidth."""

    def _drift() -> float | None:
        cells = store.achieved()
        fracs = sorted(
            abs(1.0 - c["measured_roofline_fraction"]) for c in
            cells.values() if c.get("measured_roofline_fraction")
            is not None)
        if not fracs:
            return None
        i = min(len(fracs) - 1, int(q * len(fracs)))
        return fracs[i]

    return Drift(_drift)


@dataclasses.dataclass(frozen=True)
class SLO:
    """One objective: ``series`` must stay under ``objective`` over
    ``window`` seconds. ``fast_fraction`` sizes the confirmation window
    (default 1/12, the classic 5m-of-1h pairing); ``burn`` is the
    burn-rate multiple that fires (1.0 = exactly at the objective)."""

    name: str
    objective: float
    window: float
    series: Series
    fast_fraction: float = 1.0 / 12.0
    burn: float = 1.0
    clear_ratio: float = 0.9
    patience: int = 1
    min_count: float = 0.0

    def __post_init__(self):
        if self.objective <= 0:
            raise ValueError(f"SLO {self.name!r}: objective must be > 0 "
                             "(burn rate divides by it)")
        if self.window <= 0:
            raise ValueError(f"SLO {self.name!r}: window must be > 0")
        if not 0 < self.fast_fraction <= 1:
            raise ValueError(f"SLO {self.name!r}: fast_fraction in (0, 1]")

    @property
    def fast_window(self) -> float:
        return self.window * self.fast_fraction


@dataclasses.dataclass(frozen=True)
class Alert:
    """A typed SLO state transition (the routed event, JSON-able via
    ``dataclasses.asdict``)."""

    name: str
    state: str          # 'firing' | 'resolved'
    t: float
    value: float | None
    objective: float
    burn_fast: float
    burn_slow: float
    window: float
    fast_window: float

    def describe(self) -> str:
        v = "n/a" if self.value is None else f"{self.value:.4g}"
        return (f"slo {self.name} {self.state}: value {v} vs objective "
                f"{self.objective:g} (burn {self.burn_fast:.2f}x fast / "
                f"{self.burn_slow:.2f}x slow over {self.fast_window:g}s/"
                f"{self.window:g}s)")


class _SLOState:
    __slots__ = ("firing", "above", "below", "value", "burn_fast",
                 "burn_slow")

    def __init__(self):
        self.firing = False
        self.above = 0
        self.below = 0
        self.value: float | None = None
        self.burn_fast = 0.0
        self.burn_slow = 0.0


class SLOMonitor:
    """Evaluates a set of SLOs against a ``WindowedAggregator`` and
    routes ``Alert`` transitions (registry + tracer + callbacks)."""

    enabled = True

    def __init__(self, windows, slos=(), *, registry=None, tracer=None,
                 clock: Callable[[], float] = time.monotonic,
                 on_alert=(), history: int = 256):
        self.windows = windows
        self.registry = registry
        self.tracer = tracer
        self.clock = clock
        self.on_alert = list(on_alert)
        self.alerts: collections.deque[Alert] = collections.deque(
            maxlen=history)
        self.slos: list[SLO] = []
        self._states: dict[str, _SLOState] = {}
        for slo in slos:
            self.add(slo)

    def add(self, slo: SLO) -> None:
        if slo.name in self._states:
            raise ValueError(f"duplicate SLO name {slo.name!r}")
        self.slos.append(slo)
        self._states[slo.name] = _SLOState()

    def evaluate(self) -> list[Alert]:
        """One evaluation round; returns the transitions it produced."""
        out: list[Alert] = []
        for slo in self.slos:
            st = self._states[slo.name]
            # fresh=False: evaluate() runs right after the round's
            # tick(), so the newest ticked sample is "now" — skipping
            # the per-query registry snapshot keeps the whole plane
            # inside bench_obs's <= 5% overhead bar
            slow = self.windows.window(slo.window, fresh=False)
            fast = self.windows.window(slo.fast_window, fresh=False)
            v_slow = slo.series.value(slow)
            v_fast = slo.series.value(fast)
            burn_slow = (v_slow / slo.objective) if v_slow is not None \
                else 0.0
            burn_fast = (v_fast / slo.objective) if v_fast is not None \
                else 0.0
            st.value = v_slow
            st.burn_fast = burn_fast
            st.burn_slow = burn_slow
            hot = (burn_fast >= slo.burn and burn_slow >= slo.burn
                   and slo.series.count(slow) >= slo.min_count)
            cool = burn_fast < slo.burn * slo.clear_ratio
            # BrownoutController-style hysteresis: consecutive rounds on
            # one side of the watermark pair move the state, anything
            # else resets both counters
            if hot:
                st.above += 1
                st.below = 0
            elif cool:
                st.below += 1
                st.above = 0
            else:
                st.above = 0
                st.below = 0
            if not st.firing and st.above >= slo.patience:
                st.firing = True
                st.above = 0
                out.append(self._emit(slo, st, "firing"))
            elif st.firing and st.below >= slo.patience:
                st.firing = False
                st.below = 0
                out.append(self._emit(slo, st, "resolved"))
            if self.registry is not None:
                self.registry.gauge(f"slo.{slo.name}.burn").set(burn_fast)
                self.registry.gauge(f"slo.{slo.name}.firing").set(
                    float(st.firing))
        return out

    def _emit(self, slo: SLO, st: _SLOState, state: str) -> Alert:
        alert = Alert(
            name=slo.name, state=state, t=self.clock(), value=st.value,
            objective=slo.objective, burn_fast=st.burn_fast,
            burn_slow=st.burn_slow, window=slo.window,
            fast_window=slo.fast_window)
        self.alerts.append(alert)
        if self.registry is not None:
            self.registry.counter(f"slo.alerts.{state}").inc()
        if self.tracer is not None:
            # control-plane rid -1: excluded from the span-loss audit
            self.tracer.emit(-1, "alert", slo=slo.name, state=state,
                             burn_fast=st.burn_fast,
                             burn_slow=st.burn_slow)
        for cb in self.on_alert:
            cb(alert)
        return alert

    # -- readback ---------------------------------------------------------
    def firing(self) -> list[str]:
        """Names of the SLOs currently in the firing state."""
        return [s.name for s in self.slos if self._states[s.name].firing]

    def fired(self, name: str) -> bool:
        """Whether ``name`` ever produced a 'firing' transition (survives
        resolution — the replay-assert surface)."""
        return any(a.name == name and a.state == "firing"
                   for a in self.alerts)

    def states(self) -> dict:
        out = {}
        for slo in self.slos:
            st = self._states[slo.name]
            out[slo.name] = {
                "firing": st.firing, "value": st.value,
                "burn_fast": st.burn_fast, "burn_slow": st.burn_slow,
                "objective": slo.objective, "window": slo.window,
                "fast_window": slo.fast_window, "burn": slo.burn,
            }
        return out

    def dump(self) -> dict:
        """JSON-able SLO section of the exporter payload."""
        return {"enabled": True, "slos": self.states(),
                "alerts": [dataclasses.asdict(a) for a in self.alerts]}

    def reset(self) -> None:
        self.alerts.clear()
        for name in self._states:
            self._states[name] = _SLOState()


class NullSLOMonitor:
    """``obs=False`` twin: no objectives, free ``evaluate``."""

    enabled = False
    slos: tuple = ()
    alerts: tuple = ()

    def __init__(self, *_, **__):
        pass

    def add(self, slo) -> None:
        pass

    def evaluate(self) -> list:
        return []

    def firing(self) -> list:
        return []

    def fired(self, name: str) -> bool:
        return False

    def states(self) -> dict:
        return {}

    def dump(self) -> dict:
        return {"enabled": False, "slos": {}, "alerts": []}

    def reset(self) -> None:
        pass


def default_slos(prefix: str = "serve", *, window: float = 60.0,
                 deadline_miss: float = 0.05,
                 degrade_fraction: float = 0.25,
                 p99_latency: float | None = None) -> list[SLO]:
    """A reasonable starter set over a scheduler's ``<prefix>.*``
    metrics: deadline-miss rate and degrade fraction (both ratio SLOs
    with a small ``min_count`` so a single early miss doesn't page),
    plus an optional p99 latency bound in seconds."""
    slos = [
        SLO(name=f"{prefix}_deadline_miss", objective=deadline_miss,
            window=window,
            series=CounterRatio(f"{prefix}.deadline_misses",
                                f"{prefix}.deadlined_completed"),
            min_count=8, patience=2),
        SLO(name=f"{prefix}_degrade_fraction", objective=degrade_fraction,
            window=window,
            series=CounterRatio(f"{prefix}.shed_degraded",
                                f"{prefix}.submitted"),
            min_count=8, patience=2),
    ]
    if p99_latency is not None:
        slos.append(SLO(
            name=f"{prefix}_p99_latency", objective=p99_latency,
            window=window,
            series=HistPercentile(f"{prefix}.latency_s", 99.0),
            min_count=8, patience=2))
    return slos
