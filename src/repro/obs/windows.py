"""Rolling time windows over the metrics registry.

Every registry surface is cumulative-since-start; operations questions
are windowed ("what is p99 latency over the last minute", "is the
degrade fraction rising *now*"). ``WindowedAggregator`` closes that gap
without touching any emitter: it periodically snapshots the cumulative
state of every metric in a registry into a bounded ring, and a
``window(seconds)`` query subtracts the snapshot at the window's start
from a fresh one at its end —

* **counters** — windowed delta and rate (delta / actual span),
* **gauges** — last-set value (windows don't change gauge semantics),
* **histograms** — the element-wise difference of two cumulative
  ``Histogram.state()`` bucket vectors is exactly the window's
  population, so windowed p50/p90/p99 come from the same interpolation
  the cumulative percentiles use (``registry.percentile_from_state``,
  clamped to bucket edges since min/max are not subtractable; total at
  0/1 observations by construction — never NaN).

Clock discipline matches the schedulers: ``clock=`` is injected, so a
DES bench ticking a simulated clock gets windows in simulated seconds
and fake-clock tests are bit-deterministic. ``tick()`` is called once
per scheduling round (cost: one dict copy per metric — the obs-overhead
gate in ``bench_obs`` covers it); queries take a *fresh* snapshot for
the window's end, so they are exact as of the call, not as of the last
tick. The ring is pruned to ``max_window`` seconds (plus one sample at
or before the horizon, so a full-width window always has a baseline)
and hard-capped at ``max_samples``.

``NullWindowedAggregator`` is the ``obs=False`` twin: same surface,
``tick`` is a no-op and every window is empty.
"""
from __future__ import annotations

import bisect
import threading
import time
from typing import Callable

from repro.obs.registry import (Counter, Gauge, Histogram,
                                percentile_from_state)

__all__ = ["WindowedAggregator", "NullWindowedAggregator", "WindowView"]


class _Sample:
    __slots__ = ("t", "counters", "gauges", "hists")

    def __init__(self, t: float, counters: dict, gauges: dict, hists: dict):
        self.t = t
        self.counters = counters
        self.gauges = gauges
        # name -> (counts tuple incl. overflow, count, sum)
        self.hists = hists


_EMPTY = _Sample(0.0, {}, {}, {})


class WindowView:
    """One window query's result: the delta between a baseline sample
    and a fresh end-of-window sample. ``span`` is the *actual* covered
    duration — shorter than ``requested`` while the ring is younger
    than the window (rates divide by the actual span, so a cold start
    never inflates throughput)."""

    def __init__(self, base: _Sample, cur: _Sample, *, buckets: dict,
                 requested: float):
        self._base = base
        self._cur = cur
        self._buckets = buckets
        self.requested = float(requested)
        self.start = base.t
        self.end = cur.t
        self.span = max(0.0, cur.t - base.t)

    # -- counters ---------------------------------------------------------
    def counter_delta(self, name: str) -> int:
        return (self._cur.counters.get(name, 0)
                - self._base.counters.get(name, 0))

    def rate(self, name: str) -> float:
        """Windowed events/second; 0.0 on a zero-width window."""
        return self.counter_delta(name) / self.span if self.span > 0 else 0.0

    # -- gauges -----------------------------------------------------------
    def gauge(self, name: str, default: float = 0.0) -> float:
        return self._cur.gauges.get(name, default)

    # -- histograms -------------------------------------------------------
    def _hist_delta(self, name: str):
        cur = self._cur.hists.get(name)
        if cur is None:
            return None, 0, 0.0
        base = self._base.hists.get(name)
        if base is None:
            return cur[0], cur[1], cur[2]
        dcounts = tuple(a - b for a, b in zip(cur[0], base[0]))
        return dcounts, cur[1] - base[1], cur[2] - base[2]

    def hist_count(self, name: str) -> int:
        return self._hist_delta(name)[1]

    def hist_mean(self, name: str) -> float:
        _, n, s = self._hist_delta(name)
        return s / n if n else 0.0

    def percentile(self, name: str, q: float) -> float:
        """Windowed interpolated percentile — total at every population
        size (0 observations -> 0.0; see ``percentile_from_state``)."""
        dcounts, n, _ = self._hist_delta(name)
        if dcounts is None or n <= 0:
            return 0.0
        return percentile_from_state(self._buckets[name], dcounts, q)

    # -- export -----------------------------------------------------------
    def dump(self) -> dict:
        """JSON-able windowed snapshot (the ``windows`` section of the
        exporter payload)."""
        out = {
            "requested_s": self.requested, "span_s": self.span,
            "start": self.start, "end": self.end,
            "counters": {}, "gauges": dict(self._cur.gauges),
            "histograms": {},
        }
        for name in self._cur.counters:
            out["counters"][name] = {
                "delta": self.counter_delta(name), "rate": self.rate(name)}
        for name in self._cur.hists:
            out["histograms"][name] = {
                "count": self.hist_count(name),
                "mean": self.hist_mean(name),
                "p50": self.percentile(name, 50),
                "p90": self.percentile(name, 90),
                "p99": self.percentile(name, 99),
            }
        return out


class WindowedAggregator:
    """Ring buffer of cumulative registry snapshots; windowed queries.

    ``tick()`` once per scheduling round; ``window(seconds)`` any time.
    Thread-safe: samples are immutable once appended and the ring is
    lock-guarded.
    """

    enabled = True

    def __init__(self, registry, *,
                 clock: Callable[[], float] = time.monotonic,
                 max_window: float = 900.0, max_samples: int = 4096):
        if max_window <= 0:
            raise ValueError("max_window must be > 0")
        self.registry = registry
        self.clock = clock
        self.max_window = float(max_window)
        self.max_samples = int(max_samples)
        # parallel lists (not a deque): baseline lookup is a bisect on
        # _times — O(log n) per window query instead of a ring scan,
        # which matters because the SLO monitor queries every round
        self._samples: list[_Sample] = []
        self._times: list[float] = []
        self._buckets: dict[str, tuple] = {}   # histogram name -> edges
        self._lock = threading.Lock()
        # seed the ring with a construction-time baseline so activity
        # between construction and the first tick is windowed too
        self.tick()

    def _snap(self, t: float) -> _Sample:
        counters: dict[str, int] = {}
        gauges: dict[str, float] = {}
        hists: dict[str, tuple] = {}
        for name, m in self.registry.metrics():
            if isinstance(m, Counter):
                counters[name] = m.value
            elif isinstance(m, Gauge):
                gauges[name] = m.value
            elif isinstance(m, Histogram):
                hists[name] = m.raw()
                if name not in self._buckets:
                    self._buckets[name] = m.buckets
        return _Sample(t, counters, gauges, hists)

    def tick(self) -> None:
        """Record one cumulative sample at the injected clock's now."""
        t = self.clock()
        s = self._snap(t)
        with self._lock:
            self._samples.append(s)
            self._times.append(t)
            horizon = t - self.max_window
            # keep one sample at or before the horizon: it is the
            # baseline of a full-width window
            drop = 0
            n = len(self._samples)
            while drop < n - 1 and (
                    self._times[drop + 1] <= horizon
                    or n - drop > self.max_samples):
                drop += 1
            if drop:
                del self._samples[:drop]
                del self._times[:drop]

    @property
    def samples(self) -> int:
        return len(self._samples)

    def window(self, seconds: float, *, fresh: bool = True) -> WindowView:
        """The last ``seconds`` seconds, ending at a fresh snapshot of
        now. Baseline is the newest sample at or before the window
        start (a bisect); while the ring is younger than the window the
        oldest sample serves (``view.span`` tells the actual coverage).
        ``fresh=False`` ends the window at the newest *ticked* sample
        instead of taking a new snapshot — the SLO monitor runs right
        after ``tick()`` every round, where the newest sample IS now and
        re-snapshotting the whole registry per query would quintuple the
        per-round cost."""
        if not fresh:
            with self._lock:
                if self._samples:
                    cur = self._samples[-1]
                    now = cur.t
                else:
                    cur = None
            if cur is None:
                return self.window(seconds)
        else:
            now = self.clock()
            cur = self._snap(now)
        start_t = now - float(seconds)
        with self._lock:
            i = bisect.bisect_right(self._times, start_t) - 1
            base = self._samples[max(i, 0)] if self._samples else None
        if base is None:
            # never ticked: treat the fresh snapshot as both ends so
            # deltas are zero rather than all-of-history
            base = _Sample(now, cur.counters, cur.gauges, cur.hists)
        return WindowView(base, cur, buckets=self._buckets,
                          requested=seconds)

    def reset(self) -> None:
        with self._lock:
            self._samples.clear()
            self._times.clear()


class NullWindowedAggregator:
    """``obs=False`` twin: no samples, empty windows, free ``tick``."""

    enabled = False

    def __init__(self, *_, **__):
        pass

    def tick(self) -> None:
        pass

    @property
    def samples(self) -> int:
        return 0

    def window(self, seconds: float, *, fresh: bool = True) -> WindowView:
        return WindowView(_EMPTY, _EMPTY, buckets={}, requested=seconds)

    def reset(self) -> None:
        pass
