"""Exporters: Prometheus text exposition, JSON snapshot/delta dumps,
and a stdlib HTTP scrape endpoint for the whole observability bundle.

``Exporter`` wraps one ``Observability`` bundle plus its operational
plane (windows / SLO monitor / flight recorder, attached by
``Observability.attach_operational``) and renders three surfaces:

* ``prometheus()`` — the text exposition format (version 0.0.4):
  counters (``_total`` suffix), gauges, and full cumulative histograms
  (``_bucket{le=...}`` with the ``+Inf`` bucket, ``_sum``, ``_count``),
  plus per-SLO ``slo_burn_rate``/``slo_firing`` gauges with an
  ``slo=`` label. Metric names sanitize dots to underscores
  (``serve.latency_s`` -> ``serve_latency_s``). ``parse_prometheus_text``
  is the matching validator (the CI scrape smoke's "curl parses").
* ``snapshot()`` — one JSON-able dict of the whole bundle: registry,
  traffic, profiler cells, windowed views (one per configured window
  width), SLO states + alert history, and flight-recorder dump
  summaries. ``delta(prev, cur)`` subtracts two snapshots' registry
  sections (counter deltas, histogram count/sum deltas) for cheap
  periodic shipping.
* ``serve_http()`` — a daemon-threaded stdlib HTTP server exposing
  ``/metrics`` (Prometheus) and ``/snapshot.json``; returns a handle
  with ``.port``/``.url``/``.close()``. Binds port 0 by default so
  tests and demos never collide.

``render_dashboard`` turns a snapshot into the live text dashboard
``examples/cluster_serve_demo.py --dashboard`` shows (windowed
throughput / p99 / occupancy / degrade + active alerts).

``NullExporter`` is the ``obs=False`` twin: empty snapshot, empty
exposition, no server.
"""
from __future__ import annotations

import http.server
import json
import re
import threading

from repro.obs.registry import Counter, Gauge, Histogram

__all__ = ["Exporter", "NullExporter", "prometheus_text",
           "parse_prometheus_text", "snapshot_delta", "serve_http",
           "ObsHTTPServer", "render_dashboard"]

_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")
# one exposition line: name{labels} value  — labels optional
_SAMPLE_RE = re.compile(
    r"^([a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(\{[a-zA-Z_][a-zA-Z0-9_]*=\"[^\"]*\""
    r"(?:,[a-zA-Z_][a-zA-Z0-9_]*=\"[^\"]*\")*\})?"
    r" ([0-9eE+.infa-]+)$")


def sanitize_name(name: str) -> str:
    """A registry metric name as a valid Prometheus metric name."""
    n = _NAME_RE.sub("_", name)
    if n and n[0].isdigit():
        n = "_" + n
    return n


def _fmt(v: float) -> str:
    if v == float("inf"):
        return "+Inf"
    return repr(float(v)) if isinstance(v, float) else str(v)


def prometheus_text(registry, *, slo=None) -> str:
    """Text exposition of a registry (+ SLO burn gauges). Histogram
    buckets are cumulative with the mandatory ``+Inf`` bucket equal to
    ``_count``, per the format spec."""
    lines: list[str] = []
    for name, m in registry.metrics():
        n = sanitize_name(name)
        if isinstance(m, Counter):
            lines.append(f"# TYPE {n}_total counter")
            lines.append(f"{n}_total {m.value}")
        elif isinstance(m, Gauge):
            lines.append(f"# TYPE {n} gauge")
            lines.append(f"{n} {_fmt(m.value)}")
        elif isinstance(m, Histogram):
            st = m.state()
            lines.append(f"# TYPE {n} histogram")
            cum = 0
            for edge, c in zip(m.buckets, st["counts"]):
                cum += c
                lines.append(f'{n}_bucket{{le="{_fmt(edge)}"}} {cum}')
            lines.append(f'{n}_bucket{{le="+Inf"}} {st["count"]}')
            lines.append(f"{n}_sum {_fmt(st['sum'])}")
            lines.append(f"{n}_count {st['count']}")
    if slo is not None and getattr(slo, "enabled", False):
        states = slo.states()
        if states:
            lines.append("# TYPE slo_burn_rate gauge")
            for name, st in states.items():
                lines.append(f'slo_burn_rate{{slo="{sanitize_name(name)}"}}'
                             f' {_fmt(st["burn_fast"])}')
            lines.append("# TYPE slo_firing gauge")
            for name, st in states.items():
                lines.append(f'slo_firing{{slo="{sanitize_name(name)}"}} '
                             f'{1 if st["firing"] else 0}')
    return "\n".join(lines) + "\n" if lines else ""


def parse_prometheus_text(text: str) -> dict:
    """Validate an exposition payload; returns ``{metric_name:
    [(labels, value), ...]}``. Raises ``ValueError`` on any line that is
    neither a comment nor a well-formed sample — the CI scrape smoke's
    definition of "parses as valid Prometheus exposition"."""
    out: dict[str, list] = {}
    for i, line in enumerate(text.splitlines()):
        if not line.strip() or line.startswith("#"):
            continue
        m = _SAMPLE_RE.match(line)
        if m is None:
            raise ValueError(f"line {i + 1}: not a valid exposition "
                             f"sample: {line!r}")
        name, labelstr, value = m.groups()
        labels = {}
        if labelstr:
            for pair in labelstr[1:-1].split(","):
                k, v = pair.split("=", 1)
                labels[k] = v.strip('"')
        out.setdefault(name, []).append(
            (labels, float(value.replace("+Inf", "inf"))))
    if not out:
        raise ValueError("no samples in exposition payload")
    return out


def snapshot_delta(prev: dict, cur: dict) -> dict:
    """Difference of two ``Exporter.snapshot()`` registry sections:
    counter deltas, histogram count/sum deltas, gauges at their current
    value. Metrics absent from ``prev`` delta from zero."""
    pr = prev.get("registry", {})
    cr = cur.get("registry", {})
    out = {"counters": {}, "gauges": dict(cr.get("gauges", {})),
           "histograms": {}}
    pc = pr.get("counters", {})
    for name, v in cr.get("counters", {}).items():
        out["counters"][name] = v - pc.get(name, 0)
    ph = pr.get("histograms", {})
    for name, snap in cr.get("histograms", {}).items():
        base = ph.get(name, {})
        out["histograms"][name] = {
            "count": snap["count"] - base.get("count", 0),
            "sum": snap["sum"] - base.get("sum", 0.0)}
    return out


class Exporter:
    """The full-bundle export surface (see module docstring)."""

    enabled = True

    def __init__(self, obs, *, windows=None, slo=None, flight=None,
                 window_seconds=(60.0,)):
        self.obs = obs
        self.windows = windows if windows is not None \
            else getattr(obs, "windows", None)
        self.slo = slo if slo is not None else getattr(obs, "slo", None)
        self.flight = flight if flight is not None \
            else getattr(obs, "flight", None)
        self.window_seconds = tuple(window_seconds)

    def snapshot(self) -> dict:
        snap = {"enabled": True, "registry": self.obs.registry.dump(),
                "traffic": self.obs.traffic.dump(),
                "profile": self.obs.profile.dump(),
                "windows": {}, "slo": {}, "flight": {}}
        w = self.windows
        if w is not None and w.enabled:
            for s in self.window_seconds:
                snap["windows"][f"{s:g}s"] = w.window(s).dump()
        if self.slo is not None and self.slo.enabled:
            snap["slo"] = self.slo.dump()
        fl = self.flight
        if fl is not None and fl.enabled:
            snap["flight"] = {
                "rounds": len(fl.rounds()),
                "dumps": [{"trigger": d.trigger, "reason": d.reason,
                           "t": d.t, "rounds": len(d.rounds)}
                          for d in fl.dumps]}
        return snap

    delta = staticmethod(snapshot_delta)

    def prometheus(self) -> str:
        return prometheus_text(self.obs.registry, slo=self.slo)

    def serve_http(self, host: str = "127.0.0.1",
                   port: int = 0) -> "ObsHTTPServer":
        return serve_http(self, host=host, port=port)


class NullExporter:
    """``obs=False`` twin: empty surfaces, no endpoint."""

    enabled = False

    def __init__(self, *_, **__):
        pass

    def snapshot(self) -> dict:
        return {"enabled": False, "registry": {}, "traffic": {},
                "profile": {}, "windows": {}, "slo": {}, "flight": {}}

    delta = staticmethod(snapshot_delta)

    def prometheus(self) -> str:
        return ""

    def serve_http(self, host: str = "127.0.0.1", port: int = 0) -> None:
        return None


class ObsHTTPServer:
    """Handle for a running scrape endpoint (daemon thread)."""

    def __init__(self, server: http.server.ThreadingHTTPServer,
                 thread: threading.Thread):
        self._server = server
        self._thread = thread

    @property
    def port(self) -> int:
        return self._server.server_address[1]

    @property
    def url(self) -> str:
        host = self._server.server_address[0]
        return f"http://{host}:{self.port}"

    def close(self) -> None:
        self._server.shutdown()
        self._server.server_close()
        self._thread.join(timeout=5.0)


def serve_http(exporter, *, host: str = "127.0.0.1",
               port: int = 0) -> ObsHTTPServer:
    """Start the scrape endpoint: ``GET /metrics`` (text exposition),
    ``GET /snapshot.json`` (full-bundle JSON). Port 0 picks a free
    port; read it back from the returned handle."""

    class _Handler(http.server.BaseHTTPRequestHandler):
        def do_GET(self):                                  # noqa: N802
            if self.path.split("?")[0] in ("/metrics", "/"):
                body = exporter.prometheus().encode()
                ctype = "text/plain; version=0.0.4; charset=utf-8"
            elif self.path.split("?")[0] == "/snapshot.json":
                body = json.dumps(exporter.snapshot(),
                                  default=str).encode()
                ctype = "application/json"
            else:
                self.send_response(404)
                self.end_headers()
                return
            self.send_response(200)
            self.send_header("Content-Type", ctype)
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def log_message(self, *args):   # quiet: obs must not spam stderr
            pass

    srv = http.server.ThreadingHTTPServer((host, port), _Handler)
    srv.daemon_threads = True
    thread = threading.Thread(target=srv.serve_forever, daemon=True,
                              name="obs-scrape")
    thread.start()
    return ObsHTTPServer(srv, thread)


def _find_prefix(windows: dict) -> str | None:
    for wdump in windows.values():
        for name in wdump.get("counters", {}):
            if name.endswith(".completed"):
                return name[:-len(".completed")]
    return None


def render_dashboard(snapshot: dict, *, width: int = 64) -> str:
    """The live text dashboard: windowed throughput / p99 latency /
    occupancy / degrade activity + active alerts, from one
    ``Exporter.snapshot()`` dict (works on the JSON round-trip too)."""
    windows = snapshot.get("windows", {})
    if not windows:
        return "(operational plane not attached — no windowed data)"
    wkey = next(iter(windows))
    w = windows[wkey]
    prefix = _find_prefix(windows) or "serve"
    ctr = w.get("counters", {})
    hist = w.get("histograms", {})
    gauges = w.get("gauges", {})

    def cd(name):
        return ctr.get(f"{prefix}.{name}", {"delta": 0, "rate": 0.0})

    lat = hist.get(f"{prefix}.latency_s",
                   {"count": 0, "p50": 0.0, "p99": 0.0})
    completed = cd("completed")
    bar = "-" * width
    lines = [
        bar,
        f" operational telemetry [{prefix}] — window {wkey} "
        f"(covered {w.get('span_s', 0.0):.1f}s)",
        bar,
        f" throughput   {completed['rate']:8.2f} req/s   "
        f"(completed {completed['delta']}, "
        f"submitted {cd('submitted')['delta']})",
        f" latency      p50 {lat['p50']:.4g}s  p99 {lat['p99']:.4g}s  "
        f"(n={lat['count']})",
        f" occupancy    {gauges.get(prefix + '.occupancy', 0.0):6.2f}    "
        f"queued {gauges.get(prefix + '.queued', 0.0):.0f}  "
        f"in-flight {gauges.get(prefix + '.in_flight', 0.0):.0f}",
        f" deadline     misses {cd('deadline_misses')['delta']} / "
        f"{cd('deadlined_completed')['delta']} deadlined",
        f" degrade      shed {cd('shed_degraded')['delta']}  "
        f"dropped {cd('shed_dropped')['delta']}  "
        f"level {gauges.get(prefix + '.degrade.brownout_level', 0.0):.0f}",
    ]
    slo = snapshot.get("slo", {})
    states = slo.get("slos", {}) if slo else {}
    firing = [n for n, st in states.items() if st.get("firing")]
    if states:
        if firing:
            details = ", ".join(
                f"{n} (burn {states[n]['burn_fast']:.1f}x)"
                for n in firing)
            lines.append(f" ALERTS       {details}")
        else:
            lines.append(f" alerts       none firing "
                         f"({len(states)} SLOs green)")
    fl = snapshot.get("flight", {})
    if fl:
        lines.append(f" flight       {fl.get('rounds', 0)} rounds "
                     f"retained, {len(fl.get('dumps', []))} dumps")
    lines.append(bar)
    return "\n".join(lines)
