"""Persistent measurement store + measurement-driven dispatch advice.

``KernelProfiler`` (``repro.obs.profile``) measures us/call per kernel
cell in one process; this module makes those measurements *durable* and
*actionable*:

* ``MeasurementStore`` — a JSON file of median us/call per cell, stamped
  with a hostname-free **machine fingerprint** (backend, device kind and
  count, jax/jaxlib versions, CPU model, arch). Loading a store recorded
  on a different machine raises ``MeasurementMismatch`` — cross-machine
  wall-clock comparison is meaningless, and silently mixing fingerprints
  is how perf data rots. Combined with ``repro.obs.traffic``'s modeled
  byte formulas each cell yields **achieved GB/s** and a **measured**
  roofline fraction (``achieved / launch.roofline.HBM_BW``) next to the
  modeled one — the paper's Fig-11 bandwidth story, finally measured
  instead of assumed.
* ``MeasuredDispatch`` — the advisor ``kernels/ops.py`` consults from
  ``impl='auto'`` (via ``ops.dispatch_advisor``): when BOTH tiers of a
  (kernel, shape, dtype, source) cell have steady-state data, route to
  the measured-faster tier (normalized us per lane-iteration, so cells
  recorded at different lane counts / iteration budgets still compare);
  otherwise return None and the static ``resident_fits`` budget decides,
  exactly as before. Advice can only choose among tiers the static
  semantics allow — a shape over the VMEM budget, or a sub-fp32 stepped
  pool, is never advised resident.

Store schema (version 1)::

    {"schema_version": 1,
     "fingerprint": {"id": "...", "backend": ..., "device_kind": ...,
                     "device_count": ..., "jax": ..., "jaxlib": ...,
                     "cpu": ..., "machine": ...},
     "cells": {"<kernel>|<MxN>|s<itemsize>|<impl>|<source>|L<lanes>|T<iters>":
               {"count": int, "median_us": float, "first_us": float}}}
"""
from __future__ import annotations

import hashlib
import json
import pathlib
import platform

from repro.launch.roofline import HBM_BW
from repro.obs.traffic import chunk_bytes as _chunk_bytes
from repro.obs.traffic import solve_bytes as _solve_bytes
from repro.obs.profile import parse_cell_key

__all__ = ["SCHEMA_VERSION", "MeasurementMismatch", "machine_fingerprint",
           "MeasurementStore", "MeasuredDispatch"]

SCHEMA_VERSION = 1


class MeasurementMismatch(RuntimeError):
    """The store on disk was recorded on a different machine (or with a
    different schema) than the one asking for it."""


def _cpu_model() -> str:
    try:
        with open("/proc/cpuinfo") as f:
            for line in f:
                if line.lower().startswith("model name"):
                    return line.split(":", 1)[1].strip()
    except OSError:
        pass
    return platform.processor() or "unknown"


def machine_fingerprint() -> dict:
    """Hostname-free identity of this (machine, jax stack) pair. Two
    processes with equal fingerprints produce comparable wall-clock
    numbers; nothing here identifies the host by name."""
    import jax
    import jaxlib
    dev = jax.devices()[0]
    fp = {
        "backend": jax.default_backend(),
        "device_kind": getattr(dev, "device_kind", "unknown"),
        "device_count": jax.device_count(),
        "jax": jax.__version__,
        "jaxlib": jaxlib.__version__,
        "cpu": _cpu_model(),
        "machine": platform.machine(),
    }
    fp["id"] = hashlib.sha1(
        json.dumps(fp, sort_keys=True).encode()).hexdigest()[:12]
    return fp


def _cell_bytes(p: dict) -> int | None:
    """Modeled bytes per call for a parsed cell key, from the traffic
    formulas. Implicit cells charge ``d=0`` coordinate bytes (the true
    ``(M+N)*d*4`` G-term is unknowable from the key and negligible next
    to the M*N coupling traffic it bounds from below)."""
    if p["kernel"] == "solve":
        return p["lanes"] * _solve_bytes(
            p["M"], p["N"], p["itemsize"], p["iters"], tier=p["impl"],
            source=p["source"], d=0 if p["source"] == "implicit" else None)
    if p["kernel"] == "chunk":
        return _chunk_bytes(
            p["lanes"], p["M"], p["N"], p["itemsize"], p["iters"],
            tier=p["impl"])
    return None


class MeasurementStore:
    """Median us/call per measurement cell, fingerprint-stamped.

    In-memory it is a plain dict of cells; ``save``/``load`` round-trip
    it through JSON. ``ingest`` merges a ``KernelProfiler``'s current
    cells (by key, replace — profiler cells are cumulative, so repeated
    ingests are idempotent, not double-counting).
    """

    def __init__(self, fingerprint: dict | None = None):
        self.fingerprint = (fingerprint if fingerprint is not None
                            else machine_fingerprint())
        self.cells: dict[str, dict] = {}

    # -- writing ----------------------------------------------------------
    def record(self, key: str, median_us: float, *, count: int = 1,
               first_us: float | None = None) -> None:
        self.cells[key] = {"count": int(count),
                           "median_us": float(median_us),
                           "first_us": first_us}

    def ingest(self, profiler) -> int:
        """Merge a profiler's cells (those with a steady-state median);
        returns how many cells now hold data."""
        for key, cell in profiler.cells().items():
            if cell.get("median_us") is not None:
                self.cells[key] = dict(cell)
        return len(self.cells)

    # -- persistence ------------------------------------------------------
    def to_json(self) -> dict:
        return {"schema_version": SCHEMA_VERSION,
                "fingerprint": self.fingerprint, "cells": self.cells}

    def save(self, path) -> None:
        pathlib.Path(path).write_text(
            json.dumps(self.to_json(), indent=2, sort_keys=True) + "\n")

    @classmethod
    def load(cls, path, *, fingerprint: dict | None = None,
             allow_mismatch: bool = False) -> "MeasurementStore":
        """Load a store, rejecting one recorded elsewhere: raises
        ``MeasurementMismatch`` unless the on-disk fingerprint id equals
        this machine's (or ``fingerprint=``'s), or ``allow_mismatch``."""
        data = json.loads(pathlib.Path(path).read_text())
        if data.get("schema_version") != SCHEMA_VERSION:
            raise MeasurementMismatch(
                f"{path}: schema_version {data.get('schema_version')!r} "
                f"!= {SCHEMA_VERSION}")
        want = fingerprint if fingerprint is not None else machine_fingerprint()
        got = data.get("fingerprint", {})
        if not allow_mismatch and got.get("id") != want["id"]:
            raise MeasurementMismatch(
                f"{path}: recorded on {got.get('id')!r} "
                f"({got.get('device_kind')}, jax {got.get('jax')}), this "
                f"machine is {want['id']!r} ({want['device_kind']}, jax "
                f"{want['jax']}) — wall-clock cells do not transfer")
        store = cls(fingerprint=got or want)
        store.cells = dict(data.get("cells", {}))
        return store

    # -- readback ---------------------------------------------------------
    def us_per_call(self, key: str) -> float | None:
        cell = self.cells.get(key)
        return cell["median_us"] if cell else None

    def _matching(self, *, kernel=None, M=None, N=None, itemsize=None,
                  impl=None, source=None):
        for key, cell in self.cells.items():
            if cell.get("median_us") is None:
                continue
            p = parse_cell_key(key)
            if ((kernel is None or p["kernel"] == kernel)
                    and (M is None or p["M"] == M)
                    and (N is None or p["N"] == N)
                    and (itemsize is None or p["itemsize"] == itemsize)
                    and (impl is None or p["impl"] == impl)
                    and (source is None or p["source"] == source)):
                yield p, cell

    def us_per_lane_iter(self, *, kernel, M=None, N=None, itemsize=None,
                         impl=None, source=None,
                         min_count: int = 1) -> float | None:
        """Count-weighted mean of ``median_us / (lanes * iters)`` over
        matching cells (None fields match anything) — the normalized
        cost that compares cells recorded at different lane counts /
        chunk budgets. None when no cell matches with enough samples."""
        num = den = 0.0
        for p, cell in self._matching(kernel=kernel, M=M, N=N,
                                      itemsize=itemsize, impl=impl,
                                      source=source):
            # count includes the compile call; steady samples are count-1
            n_steady = cell["count"] - 1
            if n_steady < min_count:
                continue
            w = float(n_steady)
            num += w * cell["median_us"] / max(p["lanes"] * p["iters"], 1)
            den += w
        return num / den if den else None

    def achieved(self) -> dict:
        """Per-cell achieved bandwidth from measured time over modeled
        bytes: ``{key: {median_us, modeled_bytes, achieved_gbps,
        measured_roofline_fraction}}``. The fraction is against the
        datasheet ``HBM_BW`` — honest only on real HBM; on CPU hosts it
        reports how far host execution sits from TPU bandwidth."""
        out = {}
        for key, cell in self.cells.items():
            us = cell.get("median_us")
            if us is None or us <= 0:
                continue
            nbytes = _cell_bytes(parse_cell_key(key))
            if nbytes is None:
                continue
            gbps = nbytes / (us * 1e-6) / 1e9
            out[key] = {"median_us": us, "modeled_bytes": nbytes,
                        "achieved_gbps": gbps,
                        "measured_roofline_fraction": gbps / (HBM_BW / 1e9)}
        return out


class MeasuredDispatch:
    """``impl='auto'`` advice from stored measurements.

    ``advise`` returns 'resident' / 'streamed' when both tiers of the
    cell have steady-state data, None otherwise (the caller's static
    budget then decides). ``margin`` biases toward the static choice:
    the measured tier must beat the other by that factor to flip.
    """

    def __init__(self, store: MeasurementStore, *, min_count: int = 1,
                 margin: float = 1.0):
        self.store = store
        self.min_count = min_count
        self.margin = margin

    def advise(self, *, M: int, N: int, itemsize: int,
               implicit: bool = False, stepped: bool = False) -> str | None:
        kernel = "chunk" if stepped else "solve"
        source = "implicit" if implicit else "dense"
        res = self.store.us_per_lane_iter(
            kernel=kernel, M=M, N=N, itemsize=itemsize, impl="resident",
            source=source, min_count=self.min_count)
        str_ = self.store.us_per_lane_iter(
            kernel=kernel, M=M, N=N, itemsize=itemsize, impl="streamed",
            source=source, min_count=self.min_count)
        if res is None or str_ is None:
            return None
        return "streamed" if str_ * self.margin < res else "resident"
