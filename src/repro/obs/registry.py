"""Low-overhead host-side metrics: counters, gauges, fixed-bucket histograms.

The registry is the common substrate the serving tiers' telemetry was
refactored onto (``UOTScheduler.stats()`` / ``ClusterScheduler.stats()``
read their running totals from registry counters; the public dict shapes
are unchanged). Design constraints, in order:

* **allocation-light** — a counter increment is one lock acquire and one
  int add; a histogram observation is a ``bisect`` plus two adds. No
  per-event objects, no timestamps (metrics are cumulative; *when* is the
  span tracer's job — see ``repro.obs.trace``).
* **deterministic** — nothing here reads a clock. Percentiles come from
  fixed bucket boundaries chosen at construction, so a test that drives a
  fake clock sees bit-reproducible dumps.
* **parent-chained** — a registry built with ``parent=`` forwards every
  increment/observation to the same-named metric of the parent (the
  ``ops.dispatch_counters`` stacking idiom, applied registry-wide). Each
  scheduler owns a private registry parented to the process-global one
  (``repro.obs.get_global()``), so per-scheduler ``stats()`` stay isolated
  while ``benchmarks/run.py`` dumps one process-wide ``OBS_<suite>.json``
  without touching any scheduler.
* **thread-safe** — one lock per registry guards its metric map and all
  its metrics' mutations; the async cluster step loop and background
  pollers may hammer the same counters from multiple threads
  (tests/test_obs.py races them).

Histogram percentiles are linearly interpolated inside the bucket that
holds the target rank and clamped to the observed [min, max], so they are
exact at the recorded extremes and within one bucket width of the true
order statistic everywhere else (asserted vs numpy in tests).
"""
from __future__ import annotations

import bisect
import threading


def geometric_buckets(lo: float, hi: float, factor: float = 2.0) -> tuple:
    """Geometric bucket upper edges from ``lo`` until ``hi`` is covered."""
    if lo <= 0 or factor <= 1:
        raise ValueError("need lo > 0 and factor > 1")
    edges = [lo]
    while edges[-1] < hi:
        edges.append(edges[-1] * factor)
    return tuple(edges)


# spans 1us .. ~1100s at 2x resolution: wide enough for wait/latency in
# both wall-clock and DES simulated seconds
DEFAULT_TIME_BUCKETS = geometric_buckets(1e-6, 1e3)
# iteration counts: 1 .. 16384
DEFAULT_COUNT_BUCKETS = geometric_buckets(1.0, 1e4)


class Counter:
    """Monotone running total. ``inc`` forwards to the parent chain."""

    __slots__ = ("name", "_value", "_lock", "_parent")

    def __init__(self, name: str, lock: threading.Lock, parent=None):
        self.name = name
        self._value = 0
        self._lock = lock
        self._parent = parent

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._value += n
        if self._parent is not None:
            self._parent.inc(n)

    @property
    def value(self) -> int:
        return self._value


class Gauge:
    """Last-set value (occupancy, queue depth). ``set`` forwards up."""

    __slots__ = ("name", "_value", "_lock", "_parent")

    def __init__(self, name: str, lock: threading.Lock, parent=None):
        self.name = name
        self._value = 0.0
        self._lock = lock
        self._parent = parent

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)
        if self._parent is not None:
            self._parent.set(v)

    @property
    def value(self) -> float:
        return self._value


class Histogram:
    """Fixed-bucket histogram with interpolated percentiles.

    ``buckets`` are the upper edges (ascending); values above the last
    edge land in an overflow bucket whose percentile estimate is the
    observed max. Memory is O(len(buckets)) forever — no sample is
    retained.
    """

    __slots__ = ("name", "buckets", "_counts", "_count", "_sum", "_min",
                 "_max", "_lock", "_parent")

    def __init__(self, name: str, lock: threading.Lock, parent=None,
                 buckets=DEFAULT_TIME_BUCKETS):
        self.name = name
        self.buckets = tuple(float(b) for b in buckets)
        if list(self.buckets) != sorted(set(self.buckets)):
            raise ValueError("buckets must be strictly ascending")
        self._counts = [0] * (len(self.buckets) + 1)
        self._count = 0
        self._sum = 0.0
        self._min = float("inf")
        self._max = float("-inf")
        self._lock = lock
        self._parent = parent

    def observe(self, v: float) -> None:
        v = float(v)
        i = bisect.bisect_left(self.buckets, v)
        with self._lock:
            self._counts[i] += 1
            self._count += 1
            self._sum += v
            if v < self._min:
                self._min = v
            if v > self._max:
                self._max = v
        if self._parent is not None:
            self._parent.observe(v)

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    @property
    def mean(self) -> float:
        return self._sum / self._count if self._count else 0.0

    @property
    def min(self) -> float:
        return self._min if self._count else 0.0

    @property
    def max(self) -> float:
        return self._max if self._count else 0.0

    def percentile(self, q: float) -> float:
        """Estimated q-th percentile (q in [0, 100]): linear interpolation
        inside the target rank's bucket, clamped to the observed range."""
        if not self._count:
            return 0.0
        target = q / 100.0 * self._count
        cum = 0
        for i, c in enumerate(self._counts):
            if not c:
                continue
            if cum + c >= target:
                lo = self.buckets[i - 1] if i > 0 else self._min
                hi = self.buckets[i] if i < len(self.buckets) else self._max
                frac = (target - cum) / c
                est = lo + (hi - lo) * max(0.0, min(1.0, frac))
                return max(self._min, min(self._max, est))
            cum += c
        return self._max

    def snapshot(self) -> dict:
        return {
            "count": self._count, "sum": self._sum,
            "min": self.min, "max": self.max, "mean": self.mean,
            "p50": self.percentile(50), "p90": self.percentile(90),
            "p99": self.percentile(99),
        }


class MetricsRegistry:
    """Named metric namespace; get-or-create access, JSON-able dump.

    ``counter``/``gauge``/``histogram`` return the existing metric when the
    name is known (a name maps to exactly one kind — mixing kinds raises),
    so call sites never coordinate creation. With ``parent=`` every metric
    is chained to the parent's same-named metric, created on demand.
    """

    def __init__(self, *, parent: "MetricsRegistry | None" = None):
        self.parent = parent
        self._lock = threading.Lock()
        self._metrics: dict[str, object] = {}

    def _get_or_create(self, name: str, kind, **kwargs):
        with self._lock:
            m = self._metrics.get(name)
        if m is not None:
            if not isinstance(m, kind):
                raise TypeError(f"metric {name!r} already registered as "
                                f"{type(m).__name__}, not {kind.__name__}")
            return m
        parent_m = None
        if self.parent is not None:
            parent_m = self.parent._get_or_create(name, kind, **kwargs)
        m = kind(name, self._lock, parent=parent_m, **kwargs)
        with self._lock:
            # lost the creation race: keep the first one (its parent link
            # is identical — parent metrics are get-or-create too)
            m = self._metrics.setdefault(name, m)
        return m

    def counter(self, name: str) -> Counter:
        return self._get_or_create(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get_or_create(name, Gauge)

    def histogram(self, name: str,
                  buckets=DEFAULT_TIME_BUCKETS) -> Histogram:
        return self._get_or_create(name, Histogram, buckets=buckets)

    def get(self, name: str):
        """The metric registered under ``name``, or None."""
        return self._metrics.get(name)

    def names(self) -> list[str]:
        return sorted(self._metrics)

    def dump(self) -> dict:
        """JSON-able snapshot: {'counters': {...}, 'gauges': {...},
        'histograms': {name: snapshot}} — the registry half of
        ``OBS_<suite>.json``."""
        out = {"counters": {}, "gauges": {}, "histograms": {}}
        with self._lock:
            items = list(self._metrics.items())
        for name, m in items:
            if isinstance(m, Counter):
                out["counters"][name] = m.value
            elif isinstance(m, Gauge):
                out["gauges"][name] = m.value
            elif isinstance(m, Histogram):
                out["histograms"][name] = m.snapshot()
        return out

    def reset(self) -> None:
        """Drop every metric (fresh namespace; chained children keep
        working — their parent link targets the old objects, so callers
        holding a child should re-create it after a reset; in practice
        resets happen between benchmark suites, before schedulers are
        built)."""
        with self._lock:
            self._metrics.clear()
