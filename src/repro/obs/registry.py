"""Low-overhead host-side metrics: counters, gauges, fixed-bucket histograms.

The registry is the common substrate the serving tiers' telemetry was
refactored onto (``UOTScheduler.stats()`` / ``ClusterScheduler.stats()``
read their running totals from registry counters; the public dict shapes
are unchanged). Design constraints, in order:

* **allocation-light** — a counter increment is one lock acquire and one
  int add; a histogram observation is a ``bisect`` plus two adds. No
  per-event objects, no timestamps (metrics are cumulative; *when* is the
  span tracer's job — see ``repro.obs.trace``).
* **deterministic** — nothing here reads a clock. Percentiles come from
  fixed bucket boundaries chosen at construction, so a test that drives a
  fake clock sees bit-reproducible dumps.
* **parent-chained** — a registry built with ``parent=`` forwards every
  increment/observation to the same-named metric of the parent (the
  ``ops.dispatch_counters`` stacking idiom, applied registry-wide). Each
  scheduler owns a private registry parented to the process-global one
  (``repro.obs.get_global()``), so per-scheduler ``stats()`` stay isolated
  while ``benchmarks/run.py`` dumps one process-wide ``OBS_<suite>.json``
  without touching any scheduler.
* **thread-safe** — one lock per registry guards its metric map and all
  its metrics' mutations; the async cluster step loop and background
  pollers may hammer the same counters from multiple threads
  (tests/test_obs.py races them).

Histogram percentiles are linearly interpolated inside the bucket that
holds the target rank and clamped to the observed [min, max], so they are
exact at the recorded extremes and within one bucket width of the true
order statistic everywhere else (asserted vs numpy in tests).
``percentile_from_state`` is the same estimator over a bare bucket-count
vector — the windowed-delta path (``repro.obs.windows`` subtracts two
cumulative ``Histogram.state()`` snapshots) computes percentiles through
it, and it is *total*: 0 observations return 0.0 and 1 observation
returns a value clamped inside its bucket, never NaN/None, so windowed
deltas can feed the exporters unguarded.
"""
from __future__ import annotations

import bisect
import threading


def percentile_from_state(buckets, counts, q: float,
                          lo: float | None = None,
                          hi: float | None = None) -> float:
    """Interpolated q-th percentile (q in [0, 100]) from bucket counts
    alone — ``counts`` has one overflow slot beyond ``buckets``' upper
    edges, exactly the ``Histogram.state()['counts']`` layout (or the
    element-wise difference of two such snapshots).

    Total by construction (the 0-/1-observation hardening):

    * **0 observations** -> ``0.0``. A windowed delta over a quiet
      period is an empty population; the documented sentinel is 0.0,
      matching ``Histogram.percentile`` on a fresh histogram.
    * **1 observation** -> the estimate interpolates inside the single
      occupied bucket and is clamped to that bucket's edges (to
      ``lo``/``hi`` when the caller knows the observed extremes), so it
      is finite and within one bucket width of the true value.

    ``lo``/``hi`` optionally clamp to observed extremes: the cumulative
    ``Histogram.percentile`` passes its exact min/max; windowed deltas
    cannot (min/max are not subtractable) and rely on bucket edges.
    """
    total = sum(counts)
    if total <= 0:
        return 0.0
    target = q / 100.0 * total
    cum = 0
    for i, c in enumerate(counts):
        if not c:
            continue
        if cum + c >= target:
            lo_edge = (buckets[i - 1] if i > 0
                       else (lo if lo is not None else min(0.0, buckets[0])))
            hi_edge = (buckets[i] if i < len(buckets)
                       else (hi if hi is not None else buckets[-1]))
            frac = (target - cum) / c
            est = lo_edge + (hi_edge - lo_edge) * max(0.0, min(1.0, frac))
            if lo is not None:
                est = max(lo, est)
            if hi is not None:
                est = min(hi, est)
            return est
        cum += c
    # float rounding pushed the target past the last occupied bucket
    return hi if hi is not None else buckets[-1]


def geometric_buckets(lo: float, hi: float, factor: float = 2.0) -> tuple:
    """Geometric bucket upper edges from ``lo`` until ``hi`` is covered."""
    if lo <= 0 or factor <= 1:
        raise ValueError("need lo > 0 and factor > 1")
    edges = [lo]
    while edges[-1] < hi:
        edges.append(edges[-1] * factor)
    return tuple(edges)


# spans 1us .. ~1100s at 2x resolution: wide enough for wait/latency in
# both wall-clock and DES simulated seconds
DEFAULT_TIME_BUCKETS = geometric_buckets(1e-6, 1e3)
# iteration counts: 1 .. 16384
DEFAULT_COUNT_BUCKETS = geometric_buckets(1.0, 1e4)


class Counter:
    """Monotone running total. ``inc`` forwards to the parent chain."""

    __slots__ = ("name", "_value", "_lock", "_parent")

    def __init__(self, name: str, lock: threading.Lock, parent=None):
        self.name = name
        self._value = 0
        self._lock = lock
        self._parent = parent

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._value += n
        if self._parent is not None:
            self._parent.inc(n)

    @property
    def value(self) -> int:
        return self._value


class Gauge:
    """Last-set value (occupancy, queue depth). ``set`` forwards up."""

    __slots__ = ("name", "_value", "_lock", "_parent")

    def __init__(self, name: str, lock: threading.Lock, parent=None):
        self.name = name
        self._value = 0.0
        self._lock = lock
        self._parent = parent

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)
        if self._parent is not None:
            self._parent.set(v)

    @property
    def value(self) -> float:
        return self._value


class Histogram:
    """Fixed-bucket histogram with interpolated percentiles.

    ``buckets`` are the upper edges (ascending); values above the last
    edge land in an overflow bucket whose percentile estimate is the
    observed max. Memory is O(len(buckets)) forever — no sample is
    retained.
    """

    __slots__ = ("name", "buckets", "_counts", "_count", "_sum", "_min",
                 "_max", "_lock", "_parent")

    def __init__(self, name: str, lock: threading.Lock, parent=None,
                 buckets=DEFAULT_TIME_BUCKETS):
        self.name = name
        self.buckets = tuple(float(b) for b in buckets)
        if list(self.buckets) != sorted(set(self.buckets)):
            raise ValueError("buckets must be strictly ascending")
        self._counts = [0] * (len(self.buckets) + 1)
        self._count = 0
        self._sum = 0.0
        self._min = float("inf")
        self._max = float("-inf")
        self._lock = lock
        self._parent = parent

    def observe(self, v: float) -> None:
        v = float(v)
        i = bisect.bisect_left(self.buckets, v)
        with self._lock:
            self._counts[i] += 1
            self._count += 1
            self._sum += v
            if v < self._min:
                self._min = v
            if v > self._max:
                self._max = v
        if self._parent is not None:
            self._parent.observe(v)

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    @property
    def mean(self) -> float:
        return self._sum / self._count if self._count else 0.0

    @property
    def min(self) -> float:
        return self._min if self._count else 0.0

    @property
    def max(self) -> float:
        return self._max if self._count else 0.0

    def percentile(self, q: float) -> float:
        """Estimated q-th percentile (q in [0, 100]): linear interpolation
        inside the target rank's bucket, clamped to the observed range.
        Total at every population size — 0 observations return 0.0, 1
        observation returns that observation (``percentile_from_state``'s
        clamp against the exact min/max collapses to it)."""
        if not self._count:
            return 0.0
        return percentile_from_state(self.buckets, self._counts, q,
                                     lo=self._min, hi=self._max)

    def state(self) -> dict:
        """Mergeable/subtractable cumulative state: ``{'counts', 'count',
        'sum', 'min', 'max'}`` with ``counts`` a tuple carrying the
        overflow slot. Two snapshots subtract element-wise into a
        windowed population (``repro.obs.windows``); min/max are reported
        for completeness but are NOT subtractable — windowed percentiles
        clamp to bucket edges instead (``percentile_from_state``)."""
        with self._lock:
            return {"counts": tuple(self._counts), "count": self._count,
                    "sum": self._sum,
                    "min": self._min if self._count else None,
                    "max": self._max if self._count else None}

    def raw(self) -> tuple:
        """``(counts, count, sum)`` under one lock acquire — the
        allocation-light form of ``state()`` the per-round window tick
        uses (``repro.obs.windows._snap`` runs inside the scheduler
        step, so this path is on the obs-overhead budget)."""
        with self._lock:
            return tuple(self._counts), self._count, self._sum

    def snapshot(self) -> dict:
        return {
            "count": self._count, "sum": self._sum,
            "min": self.min, "max": self.max, "mean": self.mean,
            "p50": self.percentile(50), "p90": self.percentile(90),
            "p99": self.percentile(99),
        }


class MetricsRegistry:
    """Named metric namespace; get-or-create access, JSON-able dump.

    ``counter``/``gauge``/``histogram`` return the existing metric when the
    name is known (a name maps to exactly one kind — mixing kinds raises),
    so call sites never coordinate creation. With ``parent=`` every metric
    is chained to the parent's same-named metric, created on demand.
    """

    def __init__(self, *, parent: "MetricsRegistry | None" = None):
        self.parent = parent
        self._lock = threading.Lock()
        self._metrics: dict[str, object] = {}
        self._sorted: list[tuple[str, object]] | None = None

    def _get_or_create(self, name: str, kind, **kwargs):
        with self._lock:
            m = self._metrics.get(name)
        if m is not None:
            if not isinstance(m, kind):
                raise TypeError(f"metric {name!r} already registered as "
                                f"{type(m).__name__}, not {kind.__name__}")
            return m
        parent_m = None
        if self.parent is not None:
            parent_m = self.parent._get_or_create(name, kind, **kwargs)
        m = kind(name, self._lock, parent=parent_m, **kwargs)
        with self._lock:
            # lost the creation race: keep the first one (its parent link
            # is identical — parent metrics are get-or-create too)
            m = self._metrics.setdefault(name, m)
            self._sorted = None
        return m

    def counter(self, name: str) -> Counter:
        return self._get_or_create(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get_or_create(name, Gauge)

    def histogram(self, name: str,
                  buckets=DEFAULT_TIME_BUCKETS) -> Histogram:
        return self._get_or_create(name, Histogram, buckets=buckets)

    def get(self, name: str):
        """The metric registered under ``name``, or None."""
        return self._metrics.get(name)

    def names(self) -> list[str]:
        return sorted(self._metrics)

    def metrics(self) -> list[tuple[str, object]]:
        """Stable (name, metric) snapshot of the namespace — the
        iteration surface ``repro.obs.windows`` ticks over and the
        Prometheus exporter renders from. The sorted list is cached and
        invalidated on registration (creation is rare after warmup; the
        per-round window tick calls this every time)."""
        with self._lock:
            if self._sorted is None:
                self._sorted = sorted(self._metrics.items())
            return self._sorted

    def dump(self) -> dict:
        """JSON-able snapshot: {'counters': {...}, 'gauges': {...},
        'histograms': {name: snapshot}} — the registry half of
        ``OBS_<suite>.json``."""
        out = {"counters": {}, "gauges": {}, "histograms": {}}
        with self._lock:
            items = list(self._metrics.items())
        for name, m in items:
            if isinstance(m, Counter):
                out["counters"][name] = m.value
            elif isinstance(m, Gauge):
                out["gauges"][name] = m.value
            elif isinstance(m, Histogram):
                out["histograms"][name] = m.snapshot()
        return out

    def reset(self) -> None:
        """Drop every metric (fresh namespace; chained children keep
        working — their parent link targets the old objects, so callers
        holding a child should re-create it after a reset; in practice
        resets happen between benchmark suites, before schedulers are
        built)."""
        with self._lock:
            self._metrics.clear()
            self._sorted = None
