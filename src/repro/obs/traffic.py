"""HBM-traffic accountant: modeled bytes per dispatch decision.

MAP-UOT's thesis is that UOT solving is memory-bound, so the quantity to
watch per serving decision is *bytes moved*, not wall-clock (on CPU the
latter measures the host, not the schedule). This module charges the
dispatch-table formulas from ``kernels/ops.py``'s module docstring —
the single source of truth; tests assert this module against the same
numbers — at every point a tier decision is made, and rolls them up
per route for ``OBS_<suite>.json`` and a roofline-style bytes-vs-FLOPs
summary via ``launch/roofline.py``.

Formulas (``s`` = storage itemsize, ``T`` = iterations, ``L`` = lanes in
the launch, ``G`` = cost-source read):

* ``G``: ``M*N*s`` dense, ``(M+N)*(d+1)*4`` implicit coordinates
* per-request solve: streamed ``G + 2*M*N*s*T``; resident
  ``G + 2*M*N*s`` (implicit resident: ``G + M*N*s`` — no tile read)
* scheduler chunk: streamed ``2*L*M*N*s*chunk_iters``; resident
  ``2*L*M*N*s`` per chunk (admission pays ``G`` separately, once per
  request)
* gang solve: the streamed per-request formula on the row-sharded stack
  plus ``2*N*4*T`` all-reduce bytes per device (ring all-reduce of the
  fp32 (N,) column sums: reduce-scatter + all-gather — the same 2x
  ``launch.roofline.collective_bytes`` charges)
* FLOPs: ``4*M*N`` per iteration (two rescale multiplies + two reduction
  adds per coupling element), the modeled count the roofline summary
  divides by

All charges are MODELED upper bounds at the launch's padded shapes:
``T`` is the chunk/config budget, not per-lane early exit (the device-
side tol latch is invisible to the host without extra syncs — measured
bytes are the TPU-campaign follow-on, ROADMAP item 5). Charges aggregate
by their full parameter key, so a dump's every record can be re-derived
mechanically: ``record['bytes'] == record['count'] * formula(**key)``
(tests and ``bench_chaos`` assert exactly that).

``TrafficAccountant`` parent-chains like the metrics registry: scheduler-
owned accountants forward to the process-global one, which
``benchmarks/run.py`` dumps per suite.
"""
from __future__ import annotations

import threading

from repro.launch.roofline import RooflineTerms

ROUTES = ("solve", "flush", "lane", "gang", "points")


def cost_source_bytes(M: int, N: int, s: int, *, source: str = "dense",
                      d: int | None = None) -> int:
    """``G``: the cost-source read. ``M*N*s`` for a dense kernel operand,
    ``(M+N)*(d+1)*4`` coordinate+norm floats for an implicit geometry."""
    if source == "dense":
        return M * N * s
    if source == "implicit":
        if d is None:
            raise ValueError("implicit cost source needs d")
        return (M + N) * (d + 1) * 4
    raise ValueError(f"source must be 'dense' or 'implicit', got {source!r}")


def solve_bytes(M: int, N: int, s: int, T: int, *, tier: str = "streamed",
                source: str = "dense", d: int | None = None) -> int:
    """Per-request full-solve coupling traffic: ``G + 2*M*N*s*T`` streamed,
    ``G + 2*M*N*s`` resident (``G + M*N*s`` for implicit resident — the
    tile is computed in VMEM, never read)."""
    G = cost_source_bytes(M, N, s, source=source, d=d)
    if tier == "streamed":
        return G + 2 * M * N * s * T
    if tier == "resident":
        per = 1 if source == "implicit" else 2
        return G + per * M * N * s
    raise ValueError(f"tier must be 'streamed' or 'resident', got {tier!r}")


def chunk_bytes(L: int, M: int, N: int, s: int, chunk_iters: int, *,
                tier: str = "streamed") -> int:
    """Scheduler chunk-advance traffic for an L-lane pool launch:
    ``2*L*M*N*s*chunk_iters`` streamed, ``2*L*M*N*s`` resident."""
    if tier == "streamed":
        return 2 * L * M * N * s * chunk_iters
    if tier == "resident":
        return 2 * L * M * N * s
    raise ValueError(f"tier must be 'streamed' or 'resident', got {tier!r}")


def gang_collective_bytes(N: int, T: int) -> int:
    """Per-device ICI bytes of a gang solve: ring all-reduce of the fp32
    (N,) column sums each iteration (2x: reduce-scatter + all-gather)."""
    return 2 * N * 4 * T


def modeled_flops(M: int, N: int, T: int, *, lanes: int = 1) -> int:
    """``4*M*N`` per iteration per lane (2 rescale muls + 2 reduction
    adds per coupling element; O(M+N) terms dropped)."""
    return 4 * M * N * T * lanes


class TrafficAccountant:
    """Aggregates modeled-byte charges keyed by their formula parameters.

    One charge = one dispatch decision (a solve launch, a chunk advance,
    a gang solve). ``dump()['records']`` keeps the full parameter key per
    aggregate so byte totals remain mechanically checkable against the
    formulas above.
    """

    enabled = True

    def __init__(self, *, parent: "TrafficAccountant | None" = None):
        self._parent = parent
        self._lock = threading.Lock()
        # key -> [count, bytes, coll_bytes, flops]
        self._charges: dict[tuple, list] = {}

    def _add(self, key: tuple, nbytes: int, coll: int, flops: int) -> None:
        with self._lock:
            agg = self._charges.setdefault(key, [0, 0, 0, 0])
            agg[0] += 1
            agg[1] += nbytes
            agg[2] += coll
            agg[3] += flops
        if self._parent is not None:
            self._parent._add(key, nbytes, coll, flops)

    def charge_solve(self, *, route: str, tier: str, M: int, N: int,
                     s: int, T: int, lanes: int = 1, source: str = "dense",
                     d: int | None = None) -> int:
        """A full-solve launch of ``lanes`` problems at (M, N): tier-1
        ``solve_fused`` (lanes=1), a tier-2 bucketed batch (lanes=B), or
        a gang solve (route='gang'). Returns the bytes charged."""
        nbytes = lanes * solve_bytes(M, N, s, T, tier=tier, source=source,
                                     d=d)
        coll = gang_collective_bytes(N, T) if route == "gang" else 0
        self._add(("solve", route, tier, source, M, N, s, T, lanes, d),
                  nbytes, coll, modeled_flops(M, N, T, lanes=lanes))
        return nbytes

    def charge_chunk(self, *, route: str, tier: str, L: int, M: int,
                     N: int, s: int, chunk_iters: int) -> int:
        """One scheduler chunk advance of an L-lane (M, N) pool."""
        nbytes = chunk_bytes(L, M, N, s, chunk_iters, tier=tier)
        # FLOPs run every chunk iteration regardless of tier — the
        # resident tier saves bytes, not arithmetic
        self._add(("chunk", route, tier, "dense", M, N, s, chunk_iters, L,
                   None),
                  nbytes, 0, modeled_flops(M, N, chunk_iters, lanes=L))
        return nbytes

    def charge_admission(self, *, route: str, M: int, N: int, s: int,
                         source: str = "dense", d: int | None = None,
                         count: int = 1) -> int:
        """Admission's cost-source payment: ``G`` per admitted request
        (the stepped rows of the dispatch table pay ``G`` at admission,
        not per chunk)."""
        per = cost_source_bytes(M, N, s, source=source, d=d)
        for _ in range(count):
            self._add(("admit", route, "admit", source, M, N, s, 0, 1, d),
                      per, 0, 0)
        return per * count

    # ---- rollups ----------------------------------------------------------

    def records(self) -> list[dict]:
        """Every aggregate with its full formula key — the mechanically
        checkable surface."""
        with self._lock:
            items = list(self._charges.items())
        out = []
        for (kind, route, tier, source, M, N, s, T, lanes, d), agg in items:
            out.append({"kind": kind, "route": route, "tier": tier,
                        "source": source, "M": M, "N": N, "itemsize": s,
                        "iters": T, "lanes": lanes, "d": d,
                        "count": agg[0], "bytes": agg[1],
                        "coll_bytes": agg[2], "flops": agg[3]})
        return out

    def totals(self) -> dict:
        with self._lock:
            aggs = list(self._charges.values())
        return {
            "charges": sum(a[0] for a in aggs),
            "bytes": sum(a[1] for a in aggs),
            "coll_bytes": sum(a[2] for a in aggs),
            "flops": sum(a[3] for a in aggs),
        }

    def per_route(self) -> dict:
        out: dict[str, dict] = {}
        for r in self.records():
            agg = out.setdefault(r["route"], {"charges": 0, "bytes": 0,
                                              "coll_bytes": 0, "flops": 0})
            agg["charges"] += r["count"]
            agg["bytes"] += r["bytes"]
            agg["coll_bytes"] += r["coll_bytes"]
            agg["flops"] += r["flops"]
        return out

    def bytes_per_solve(self) -> float:
        """Mean modeled bytes per charged solve/chunk decision."""
        t = self.totals()
        return t["bytes"] / t["charges"] if t["charges"] else 0.0

    def roofline(self) -> dict:
        """Bytes-vs-FLOPs summary on the TPU-v5e roofline constants
        (``launch.roofline``): which side of the machine the modeled
        workload would saturate, and the arithmetic intensity."""
        t = self.totals()
        terms = RooflineTerms(float(t["flops"]), float(t["bytes"]),
                              float(t["coll_bytes"]))
        out = terms.as_dict()
        out["arithmetic_intensity"] = (t["flops"] / t["bytes"]
                                       if t["bytes"] else 0.0)
        return out

    def dump(self) -> dict:
        """The traffic half of ``OBS_<suite>.json``."""
        return {"totals": self.totals(), "per_route": self.per_route(),
                "bytes_per_solve": self.bytes_per_solve(),
                "roofline": self.roofline(), "records": self.records()}

    def reset(self) -> None:
        with self._lock:
            self._charges.clear()


class NullAccountant:
    """Disabled accountant: same surface, charges dropped."""

    enabled = False

    def charge_solve(self, **kw) -> int:
        return 0

    def charge_chunk(self, **kw) -> int:
        return 0

    def charge_admission(self, **kw) -> int:
        return 0

    def records(self) -> list:
        return []

    def totals(self) -> dict:
        return {"charges": 0, "bytes": 0, "coll_bytes": 0, "flops": 0}

    def per_route(self) -> dict:
        return {}

    def bytes_per_solve(self) -> float:
        return 0.0

    def roofline(self) -> dict:
        return RooflineTerms(0.0, 0.0, 0.0).as_dict()

    def dump(self) -> dict:
        return {"totals": self.totals(), "per_route": {},
                "bytes_per_solve": 0.0, "roofline": self.roofline(),
                "records": []}

    def reset(self) -> None:
        pass
