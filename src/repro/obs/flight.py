"""Black-box flight recorder: a bounded ring of per-round scheduler
state, frozen into replayable incident captures by ``dump_on`` triggers.

Both schedulers (``serve/scheduler.py``, ``cluster/scheduler.py``) feed
one of these per instance:

* during a round, lifecycle notes accumulate via ``note(kind, ...)`` —
  placements, shed/degrade decisions, injected faults, unhealthy
  evictions, requeues, quarantines, gang timeouts, alert transitions;
* at the end of every round ``record_round(step, **state)`` closes the
  round: queue depth, in-flight count, occupancy, device-health summary
  plus that round's notes, appended to a ring of the last ``capacity``
  rounds. O(capacity) memory forever, like ``occupancy_log``.

A **dump** freezes the ring: ``dump(trigger, reason=...)`` snapshots
every retained round into an immutable ``FlightDump`` and keeps it in a
bounded ``dumps`` deque. The schedulers wire the triggers the incident
response actually needs — a firing SLO alert (``alert:<name>``), device
quarantine, a gang-timeout breach, and a terminal ``RequestFailure`` —
so the moment something goes wrong, the black box already holds the N
rounds that led up to it.

Capture format is JSONL (``write_jsonl``/``load_jsonl`` round-trip): a
header line ``{"flight": {...}}`` with trigger/reason/meta, then one
round per line. ``render`` draws the text-timeline treatment
``trace.render_timeline`` established — one row per round with an
occupancy bar and event glyphs — for eyeballs; the JSONL is the machine
surface (``examples/cluster_serve_demo.py --record/--replay``).

``NullFlightRecorder`` is the ``obs=False`` twin: free ``note`` /
``record_round``, never a dump.
"""
from __future__ import annotations

import collections
import dataclasses
import json
import threading
import time
from typing import Callable

__all__ = ["FlightRecorder", "NullFlightRecorder", "FlightDump"]

# glyphs for the rendered timeline (trace.render_timeline's initials
# idiom applied to round events)
_GLYPHS = {
    "place": "P", "shed": "x", "degrade": "D", "fault": "F",
    "unhealthy": "u", "failure": "X", "requeue": "r", "quarantine": "Q",
    "gang_timeout": "G", "alert": "A", "escalate": "!",
}


@dataclasses.dataclass(frozen=True)
class FlightDump:
    """One frozen capture: the rounds retained at trigger time."""

    trigger: str
    reason: str
    t: float
    rounds: tuple
    meta: dict

    def to_header(self) -> dict:
        return {"flight": {"trigger": self.trigger, "reason": self.reason,
                           "t": self.t, "rounds": len(self.rounds),
                           "meta": self.meta}}


class FlightRecorder:
    """Bounded per-round black box with triggered dumps."""

    enabled = True

    def __init__(self, *, capacity: int = 256, keep_dumps: int = 8,
                 clock: Callable[[], float] = time.monotonic):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self.clock = clock
        self._rounds: collections.deque[dict] = collections.deque(
            maxlen=capacity)
        self._events: list[dict] = []
        self.dumps: collections.deque[FlightDump] = collections.deque(
            maxlen=keep_dumps)
        self._lock = threading.Lock()

    # -- capture ----------------------------------------------------------
    def note(self, kind: str, **fields) -> None:
        """Buffer one lifecycle event into the currently-open round."""
        e = {"kind": kind, "t": self.clock()}
        e.update(fields)
        with self._lock:
            self._events.append(e)

    def record_round(self, step: int, **state) -> None:
        """Close the open round: scheduler state + accumulated notes."""
        with self._lock:
            ev, self._events = self._events, []
            r = {"t": self.clock(), "step": int(step), "events": ev}
            r.update(state)
            self._rounds.append(r)

    def rounds(self) -> list[dict]:
        with self._lock:
            return list(self._rounds)

    # -- dumps ------------------------------------------------------------
    def dump(self, trigger: str, *, reason: str = "",
             context: dict | None = None) -> FlightDump:
        """Freeze the ring (plus any not-yet-closed notes) into a
        capture; retained in the bounded ``dumps`` deque."""
        with self._lock:
            rounds = [dict(r) for r in self._rounds]
            if self._events:
                rounds.append({"t": self.clock(), "step": None,
                               "events": list(self._events),
                               "open": True})
        d = FlightDump(trigger=trigger, reason=reason, t=self.clock(),
                       rounds=tuple(rounds), meta=dict(context or {}))
        self.dumps.append(d)
        return d

    def triggered(self, prefix: str) -> bool:
        """Whether any retained dump's trigger starts with ``prefix``
        (the replay-assert surface: ``triggered('alert:')``)."""
        return any(d.trigger.startswith(prefix) for d in self.dumps)

    # -- persistence ------------------------------------------------------
    def write_jsonl(self, path, dump: FlightDump | None = None) -> int:
        """Header line + one round per line; returns lines written.
        Without ``dump``, the newest retained capture is written (a
        fresh ``manual`` capture if none exists)."""
        if dump is None:
            dump = self.dumps[-1] if self.dumps else self.dump("manual")
        with open(path, "w") as f:
            f.write(json.dumps(dump.to_header()) + "\n")
            for r in dump.rounds:
                f.write(json.dumps(r) + "\n")
        return 1 + len(dump.rounds)

    @staticmethod
    def load_jsonl(path) -> FlightDump:
        with open(path) as f:
            lines = [json.loads(ln) for ln in f if ln.strip()]
        if not lines or "flight" not in lines[0]:
            raise ValueError(f"{path}: not a flight capture (missing "
                             "header line)")
        hdr = lines[0]["flight"]
        return FlightDump(trigger=hdr["trigger"], reason=hdr["reason"],
                          t=hdr["t"], rounds=tuple(lines[1:]),
                          meta=hdr.get("meta", {}))

    # -- human rendering --------------------------------------------------
    @staticmethod
    def render(dump: FlightDump, *, bar_width: int = 10,
               max_rounds: int | None = None) -> str:
        """Text timeline of a capture: one row per round — step, time,
        queue depth, in-flight, an occupancy bar, event glyphs. For
        eyeballs, not parsers — JSONL is the machine surface."""
        rounds = list(dump.rounds)
        if max_rounds is not None and len(rounds) > max_rounds:
            rounds = rounds[-max_rounds:]
        lines = [f"flight capture — trigger={dump.trigger} "
                 f"t={dump.t:.6f} ({len(dump.rounds)} rounds)"]
        if dump.reason:
            lines.append(f"  reason: {dump.reason}")
        lines.append(f"{'step':>6} {'t':>12} {'queued':>6} {'fly':>4} "
                     f"{'occupancy':<{bar_width + 6}} events")
        for r in rounds:
            occ = float(r.get("occupancy", 0.0))
            filled = max(0, min(bar_width,
                                int(round(occ * bar_width))))
            bar = "#" * filled + "." * (bar_width - filled)
            glyphs = []
            for e in r.get("events", ()):
                g = _GLYPHS.get(e.get("kind"), "?")
                rid = e.get("rid")
                detail = (str(rid) if rid is not None
                          else str(e.get("device", e.get("slo", ""))))
                glyphs.append(g + detail)
            step = r.get("step")
            lines.append(
                f"{'open' if step is None else step:>6} "
                f"{r.get('t', 0.0):>12.6f} {r.get('queued', 0):>6} "
                f"{r.get('in_flight', 0):>4} "
                f"|{bar}| {occ:.2f} {' '.join(glyphs)}".rstrip())
        return "\n".join(lines)

    def reset(self) -> None:
        with self._lock:
            self._rounds.clear()
            self._events.clear()
        self.dumps.clear()


class NullFlightRecorder:
    """``obs=False`` twin: records nothing, never dumps."""

    enabled = False
    dumps: tuple = ()

    def __init__(self, *_, **__):
        pass

    def note(self, kind: str, **fields) -> None:
        pass

    def record_round(self, step: int, **state) -> None:
        pass

    def rounds(self) -> list:
        return []

    def dump(self, trigger: str, *, reason: str = "",
             context: dict | None = None) -> None:
        return None

    def triggered(self, prefix: str) -> bool:
        return False

    def write_jsonl(self, path, dump=None) -> int:
        with open(path, "w"):
            pass
        return 0

    load_jsonl = staticmethod(FlightRecorder.load_jsonl)
    render = staticmethod(FlightRecorder.render)

    def reset(self) -> None:
        pass
