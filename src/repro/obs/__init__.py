"""Unified observability: metrics registry + trace spans + HBM accounting.

The three instruments the serving ladder reports through (see
``repro.serve``'s "Observability" section for the scheduler-facing view):

* ``registry`` — ``MetricsRegistry``: counters / gauges / fixed-bucket
  histograms. Always live: the schedulers' ``stats()`` running totals ARE
  registry counters now (the dicts' public shapes are unchanged).
* ``tracer`` — ``SpanTracer``: per-request lifecycle events
  (submit → queue → place → chunk* → evict → complete → poll), JSONL
  export, text timelines, and the zero-span-loss audit
  (``check_complete``).
* ``traffic`` — ``TrafficAccountant``: modeled HBM bytes charged per
  dispatch decision using the ``kernels/ops.py`` dispatch-table formulas,
  plus a roofline bytes-vs-FLOPs summary (``launch/roofline.py``).

``Observability`` bundles the three with one enable switch and one
injected clock. ``enabled=False`` swaps the tracer and accountant for
their null twins — the registry stays live because ``stats()`` depends
on it; counter increments are the part of the overhead budget that is
not optional. The obs-overhead CI job holds the *enabled* path to <= 5%
throughput/p99 overhead over disabled on the scheduler DES.

Per-process aggregation: every ``Observability`` defaults to parenting
its registry and accountant to the process-global bundle
(``get_global()``), mirroring ``ops.dispatch_counters``'s stack idiom —
scheduler-local metrics stay isolated for ``stats()`` while
``benchmarks/run.py`` dumps one ``OBS_<suite>.json`` per suite from the
global and resets it between suites (``reset_global()``). Tracers are
NOT globally merged: rid spaces are per scheduler, so spans live with
their scheduler (``sched.obs.tracer``).

Measured performance
--------------------
The accountant's bytes are *modeled*; two further members carry the
*measured* half (``repro.obs.profile`` / ``repro.obs.measure``):

* ``phases`` — ``PhaseTimer``: scheduler round phases under
  ``profile.phase.<name>`` (total) and ``...<name>.self`` (exclusive of
  nested phases). Names: ``serve.{evict,admit,chunk,poll}`` and
  ``cluster.{prep,evict,admit,gang,chunk,poll}``, in seconds.
* ``profile`` — ``KernelProfiler``: every dispatched solve/chunk timed
  per measurement cell ``kernel|MxN|s<itemsize>|impl|source|L|T`` (the
  traffic formulas' own parameters), first-call (trace+compile) under
  ``profile.compile.<cell>`` split from steady-state execute under
  ``profile.kernel.<cell>``. The hook is installed around launches via
  ``ops.launch_profiler`` and forces a device sync per timed launch —
  which is why ``enabled=False`` swaps in null twins that install
  nothing.

``measure.MeasurementStore`` persists a profiler's cells as
fingerprint-stamped JSON (schema in its docstring); dividing each
cell's modeled bytes by its measured seconds yields achieved GB/s and
a **measured** roofline fraction (``store.achieved()``) next to the
accountant's modeled one. Stored cells feed back into serving:
``measure.MeasuredDispatch`` advises ``ops`` ``impl='auto'`` when both
tiers of a cell have data, and ``core.predict.measured_seconds_per_iter``
turns predicted iterations into predicted seconds from measured chunk
cost (both schedulers accept ``measurements=``).

Operational telemetry
---------------------
Every surface above is cumulative-since-start; the *operational plane*
(``attach_operational``) adds the windowed / alerting / incident-capture
layer on top. Four members, each with an ``obs=False`` null twin:

* ``windows`` — ``windows.WindowedAggregator``: ring of cumulative
  registry snapshots on the scheduler's injected clock, ticked once per
  round; ``windows.window(N)`` yields per-window counter deltas/rates,
  gauge last-values, and histogram-delta p50/p90/p99 (total at 0/1
  observations — ``registry.percentile_from_state`` never emits NaN).
* ``slo`` — ``slo.SLOMonitor`` over declarative ``slo.SLO(name,
  objective, window, series)`` objectives, evaluated per round with
  multi-window (fast/slow) burn-rate rules and BrownoutController-style
  hysteresis. Transitions are typed ``slo.Alert`` events routed through
  the registry (``slo.alerts.firing``/``.resolved`` counters,
  ``slo.<name>.burn``/``.firing`` gauges), the span tracer (an
  ``alert`` event under control-plane rid ``-1``), and ``on_alert``
  callbacks.
* ``flight`` — ``flight.FlightRecorder``: bounded black-box ring of
  per-round scheduler state (queue depth, in-flight, occupancy, device
  health) plus lifecycle notes (placements, sheds, faults, requeues).
  Both schedulers wire ``dump_on`` triggers — a firing alert
  (``alert:<slo>``), device ``quarantine``, ``gang_timeout``, and a
  terminal ``request_failure`` — each freezing the ring into a
  replayable JSONL capture (``write_jsonl``/``load_jsonl``/``render``).
* ``exporter`` — ``export.Exporter``: Prometheus text exposition
  (``prometheus()``; validated by ``export.parse_prometheus_text``),
  whole-bundle JSON ``snapshot()``/``delta()``, and the stdlib scrape
  endpoint ``serve_http()`` (``/metrics`` + ``/snapshot.json``).

Metric names the plane adds (joining the schedulers' ``serve.*`` /
``cluster.*`` namespaces):

======================== ==============================================
``slo.alerts.firing``    counter: alert transitions into firing
``slo.alerts.resolved``  counter: alert transitions into resolved
``slo.<name>.burn``      gauge: the SLO's fast-window burn rate
``slo.<name>.firing``    gauge: 0/1 current alert state
======================== ==============================================

Schema crib: an ``Alert`` is ``{name, state: firing|resolved, t, value,
objective, burn_fast, burn_slow, window, fast_window}``; a flight
capture is a JSONL header ``{"flight": {trigger, reason, t, rounds,
meta}}`` followed by one round per line ``{t, step, events: [{kind, t,
...}], queued, in_flight, occupancy, ...}``.
"""
from __future__ import annotations

import time
from typing import Callable

from repro.obs.registry import (Counter, Gauge, Histogram, MetricsRegistry,
                                DEFAULT_COUNT_BUCKETS, DEFAULT_TIME_BUCKETS,
                                geometric_buckets, percentile_from_state)
from repro.obs.trace import NullTracer, SpanTracer, TERMINAL_STATUSES
from repro.obs.traffic import (NullAccountant, TrafficAccountant,
                               chunk_bytes, cost_source_bytes,
                               gang_collective_bytes, modeled_flops,
                               solve_bytes)
from repro.obs.profile import (KernelProfiler, NullKernelProfiler,
                               NullPhaseTimer, PhaseTimer, cell_key,
                               parse_cell_key)
from repro.obs.measure import (MeasuredDispatch, MeasurementMismatch,
                               MeasurementStore, machine_fingerprint)
from repro.obs.windows import (NullWindowedAggregator, WindowedAggregator,
                               WindowView)
from repro.obs.slo import (SLO, Alert, CounterDelta, CounterRate,
                           CounterRatio, Drift, GaugeSeries,
                           HistPercentile, NullSLOMonitor, SLOMonitor,
                           Series, default_slos, roofline_drift)
from repro.obs.flight import FlightDump, FlightRecorder, NullFlightRecorder
from repro.obs.export import (Exporter, NullExporter, ObsHTTPServer,
                              parse_prometheus_text, prometheus_text,
                              render_dashboard, serve_http, snapshot_delta)

__all__ = [
    "Observability", "MetricsRegistry", "Counter", "Gauge", "Histogram",
    "SpanTracer", "NullTracer", "TrafficAccountant", "NullAccountant",
    "PhaseTimer", "NullPhaseTimer", "KernelProfiler", "NullKernelProfiler",
    "MeasurementStore", "MeasuredDispatch", "MeasurementMismatch",
    "machine_fingerprint", "cell_key", "parse_cell_key",
    "TERMINAL_STATUSES", "DEFAULT_TIME_BUCKETS", "DEFAULT_COUNT_BUCKETS",
    "geometric_buckets", "percentile_from_state", "cost_source_bytes",
    "solve_bytes", "chunk_bytes", "gang_collective_bytes", "modeled_flops",
    "get_global", "reset_global", "global_dump",
    # operational plane (windows / SLO / flight / exporters)
    "WindowedAggregator", "NullWindowedAggregator", "WindowView",
    "SLO", "Alert", "SLOMonitor", "NullSLOMonitor", "Series",
    "CounterRatio", "CounterDelta", "CounterRate", "HistPercentile",
    "GaugeSeries", "Drift", "roofline_drift", "default_slos",
    "FlightRecorder", "NullFlightRecorder", "FlightDump",
    "Exporter", "NullExporter", "ObsHTTPServer", "serve_http",
    "prometheus_text", "parse_prometheus_text", "snapshot_delta",
    "render_dashboard",
]


class Observability:
    """One scheduler's (or one suite's) instrument bundle.

    ``enabled=False`` keeps the registry live (stats' counters must keep
    counting) but swaps tracing and traffic accounting for no-ops.
    ``parent`` defaults to the process-global bundle; pass
    ``parent=None`` explicitly via ``chain=False`` to isolate (tests).
    """

    def __init__(self, *, enabled: bool = True,
                 clock: Callable[[], float] = time.monotonic,
                 chain: bool = True,
                 parent: "Observability | None" = None):
        if parent is None and chain:
            parent = get_global()
        self.enabled = enabled
        self.parent = parent
        self.clock = clock
        self.registry = MetricsRegistry(
            parent=parent.registry if parent is not None else None)
        # operational plane: null until attach_operational() builds it
        # (schedulers attach; the attributes always exist so callers
        # never need hasattr guards)
        self.windows = NullWindowedAggregator()
        self.slo = NullSLOMonitor()
        self.flight = NullFlightRecorder()
        self.exporter = NullExporter()
        if enabled:
            self.tracer = SpanTracer(clock=clock)
            self.traffic = TrafficAccountant(
                parent=parent.traffic if parent is not None else None)
            # wall-clock instruments (see "Measured performance" above):
            # these time the HOST, so they run on perf_counter regardless
            # of the scheduler's (possibly simulated) clock
            self.phases = PhaseTimer(self.registry)
            self.profile = KernelProfiler(
                self.registry,
                parent=(parent.profile if parent is not None
                        and parent.profile.enabled else None))
        else:
            self.tracer = NullTracer(clock=clock)
            self.traffic = NullAccountant()
            self.phases = NullPhaseTimer()
            self.profile = NullKernelProfiler()

    def attach_operational(self, *, slos=(), clock=None,
                           max_window: float = 900.0,
                           flight_capacity: int = 256,
                           keep_dumps: int = 8, on_alert=(),
                           window_seconds=(60.0,)) -> "Observability":
        """Build the operational plane (windows + SLO monitor + flight
        recorder + exporter) onto this bundle — see the module
        docstring's "Operational telemetry" section. Under
        ``enabled=False`` the members stay their null twins, so the
        whole plane costs three no-op attribute calls per round.
        ``clock`` defaults to the bundle's own (schedulers pass their
        possibly-simulated clock so windows run in DES seconds)."""
        clock = clock if clock is not None else self.clock
        if self.enabled:
            self.windows = WindowedAggregator(
                self.registry, clock=clock, max_window=max_window)
            self.flight = FlightRecorder(
                capacity=flight_capacity, keep_dumps=keep_dumps,
                clock=clock)
            self.slo = SLOMonitor(
                self.windows, slos, registry=self.registry,
                tracer=self.tracer, clock=clock, on_alert=on_alert)
            self.exporter = Exporter(
                self, windows=self.windows, slo=self.slo,
                flight=self.flight, window_seconds=window_seconds)
        return self

    def dump(self) -> dict:
        """Registry + traffic + profile (+ operational plane, when
        attached) snapshot — the ``OBS_<suite>.json`` payload; spans
        export separately via ``tracer.write_jsonl``."""
        out = {"enabled": self.enabled, "registry": self.registry.dump(),
               "traffic": self.traffic.dump(),
               "profile": self.profile.dump()}
        if self.slo.enabled:
            out["slo"] = self.slo.dump()
        if self.windows.enabled:
            out["windows_samples"] = self.windows.samples
        return out


class _GlobalObservability(Observability):
    """The process-global aggregation root (no parent, no clock user)."""

    def __init__(self):
        super().__init__(enabled=True, chain=False, parent=None)

    def reset(self) -> None:
        self.registry.reset()
        self.traffic.reset()
        self.tracer.clear()
        self.profile.reset()
        self.windows.reset()
        self.slo.reset()
        self.flight.reset()


_GLOBAL: _GlobalObservability | None = None


def get_global() -> _GlobalObservability:
    """The process-global ``Observability`` every child chains to by
    default (created on first use)."""
    global _GLOBAL
    if _GLOBAL is None:
        _GLOBAL = _GlobalObservability()
    return _GLOBAL


def reset_global() -> None:
    """Zero the global registry and accountant (between benchmark suites;
    schedulers built BEFORE a reset keep counting into the old, orphaned
    parent metrics — build them after)."""
    get_global().reset()


def global_dump() -> dict:
    """Snapshot of the process-global bundle."""
    return get_global().dump()
