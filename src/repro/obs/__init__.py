"""Unified observability: metrics registry + trace spans + HBM accounting.

The three instruments the serving ladder reports through (see
``repro.serve``'s "Observability" section for the scheduler-facing view):

* ``registry`` — ``MetricsRegistry``: counters / gauges / fixed-bucket
  histograms. Always live: the schedulers' ``stats()`` running totals ARE
  registry counters now (the dicts' public shapes are unchanged).
* ``tracer`` — ``SpanTracer``: per-request lifecycle events
  (submit → queue → place → chunk* → evict → complete → poll), JSONL
  export, text timelines, and the zero-span-loss audit
  (``check_complete``).
* ``traffic`` — ``TrafficAccountant``: modeled HBM bytes charged per
  dispatch decision using the ``kernels/ops.py`` dispatch-table formulas,
  plus a roofline bytes-vs-FLOPs summary (``launch/roofline.py``).

``Observability`` bundles the three with one enable switch and one
injected clock. ``enabled=False`` swaps the tracer and accountant for
their null twins — the registry stays live because ``stats()`` depends
on it; counter increments are the part of the overhead budget that is
not optional. The obs-overhead CI job holds the *enabled* path to <= 5%
throughput/p99 overhead over disabled on the scheduler DES.

Per-process aggregation: every ``Observability`` defaults to parenting
its registry and accountant to the process-global bundle
(``get_global()``), mirroring ``ops.dispatch_counters``'s stack idiom —
scheduler-local metrics stay isolated for ``stats()`` while
``benchmarks/run.py`` dumps one ``OBS_<suite>.json`` per suite from the
global and resets it between suites (``reset_global()``). Tracers are
NOT globally merged: rid spaces are per scheduler, so spans live with
their scheduler (``sched.obs.tracer``).

Measured performance
--------------------
The accountant's bytes are *modeled*; two further members carry the
*measured* half (``repro.obs.profile`` / ``repro.obs.measure``):

* ``phases`` — ``PhaseTimer``: scheduler round phases under
  ``profile.phase.<name>`` (total) and ``...<name>.self`` (exclusive of
  nested phases). Names: ``serve.{evict,admit,chunk,poll}`` and
  ``cluster.{prep,evict,admit,gang,chunk,poll}``, in seconds.
* ``profile`` — ``KernelProfiler``: every dispatched solve/chunk timed
  per measurement cell ``kernel|MxN|s<itemsize>|impl|source|L|T`` (the
  traffic formulas' own parameters), first-call (trace+compile) under
  ``profile.compile.<cell>`` split from steady-state execute under
  ``profile.kernel.<cell>``. The hook is installed around launches via
  ``ops.launch_profiler`` and forces a device sync per timed launch —
  which is why ``enabled=False`` swaps in null twins that install
  nothing.

``measure.MeasurementStore`` persists a profiler's cells as
fingerprint-stamped JSON (schema in its docstring); dividing each
cell's modeled bytes by its measured seconds yields achieved GB/s and
a **measured** roofline fraction (``store.achieved()``) next to the
accountant's modeled one. Stored cells feed back into serving:
``measure.MeasuredDispatch`` advises ``ops`` ``impl='auto'`` when both
tiers of a cell have data, and ``core.predict.measured_seconds_per_iter``
turns predicted iterations into predicted seconds from measured chunk
cost (both schedulers accept ``measurements=``).
"""
from __future__ import annotations

import time
from typing import Callable

from repro.obs.registry import (Counter, Gauge, Histogram, MetricsRegistry,
                                DEFAULT_COUNT_BUCKETS, DEFAULT_TIME_BUCKETS,
                                geometric_buckets)
from repro.obs.trace import NullTracer, SpanTracer, TERMINAL_STATUSES
from repro.obs.traffic import (NullAccountant, TrafficAccountant,
                               chunk_bytes, cost_source_bytes,
                               gang_collective_bytes, modeled_flops,
                               solve_bytes)
from repro.obs.profile import (KernelProfiler, NullKernelProfiler,
                               NullPhaseTimer, PhaseTimer, cell_key,
                               parse_cell_key)
from repro.obs.measure import (MeasuredDispatch, MeasurementMismatch,
                               MeasurementStore, machine_fingerprint)

__all__ = [
    "Observability", "MetricsRegistry", "Counter", "Gauge", "Histogram",
    "SpanTracer", "NullTracer", "TrafficAccountant", "NullAccountant",
    "PhaseTimer", "NullPhaseTimer", "KernelProfiler", "NullKernelProfiler",
    "MeasurementStore", "MeasuredDispatch", "MeasurementMismatch",
    "machine_fingerprint", "cell_key", "parse_cell_key",
    "TERMINAL_STATUSES", "DEFAULT_TIME_BUCKETS", "DEFAULT_COUNT_BUCKETS",
    "geometric_buckets", "cost_source_bytes", "solve_bytes", "chunk_bytes",
    "gang_collective_bytes", "modeled_flops", "get_global", "reset_global",
    "global_dump",
]


class Observability:
    """One scheduler's (or one suite's) instrument bundle.

    ``enabled=False`` keeps the registry live (stats' counters must keep
    counting) but swaps tracing and traffic accounting for no-ops.
    ``parent`` defaults to the process-global bundle; pass
    ``parent=None`` explicitly via ``chain=False`` to isolate (tests).
    """

    def __init__(self, *, enabled: bool = True,
                 clock: Callable[[], float] = time.monotonic,
                 chain: bool = True,
                 parent: "Observability | None" = None):
        if parent is None and chain:
            parent = get_global()
        self.enabled = enabled
        self.parent = parent
        self.registry = MetricsRegistry(
            parent=parent.registry if parent is not None else None)
        if enabled:
            self.tracer = SpanTracer(clock=clock)
            self.traffic = TrafficAccountant(
                parent=parent.traffic if parent is not None else None)
            # wall-clock instruments (see "Measured performance" above):
            # these time the HOST, so they run on perf_counter regardless
            # of the scheduler's (possibly simulated) clock
            self.phases = PhaseTimer(self.registry)
            self.profile = KernelProfiler(
                self.registry,
                parent=(parent.profile if parent is not None
                        and parent.profile.enabled else None))
        else:
            self.tracer = NullTracer(clock=clock)
            self.traffic = NullAccountant()
            self.phases = NullPhaseTimer()
            self.profile = NullKernelProfiler()

    def dump(self) -> dict:
        """Registry + traffic + profile snapshot (the ``OBS_<suite>.json``
        payload; spans export separately via ``tracer.write_jsonl``)."""
        return {"enabled": self.enabled, "registry": self.registry.dump(),
                "traffic": self.traffic.dump(),
                "profile": self.profile.dump()}


class _GlobalObservability(Observability):
    """The process-global aggregation root (no parent, no clock user)."""

    def __init__(self):
        super().__init__(enabled=True, chain=False, parent=None)

    def reset(self) -> None:
        self.registry.reset()
        self.traffic.reset()
        self.tracer.clear()
        self.profile.reset()


_GLOBAL: _GlobalObservability | None = None


def get_global() -> _GlobalObservability:
    """The process-global ``Observability`` every child chains to by
    default (created on first use)."""
    global _GLOBAL
    if _GLOBAL is None:
        _GLOBAL = _GlobalObservability()
    return _GLOBAL


def reset_global() -> None:
    """Zero the global registry and accountant (between benchmark suites;
    schedulers built BEFORE a reset keep counting into the old, orphaned
    parent metrics — build them after)."""
    get_global().reset()


def global_dump() -> dict:
    """Snapshot of the process-global bundle."""
    return get_global().dump()
