"""Unified observability: metrics registry + trace spans + HBM accounting.

The three instruments the serving ladder reports through (see
``repro.serve``'s "Observability" section for the scheduler-facing view):

* ``registry`` — ``MetricsRegistry``: counters / gauges / fixed-bucket
  histograms. Always live: the schedulers' ``stats()`` running totals ARE
  registry counters now (the dicts' public shapes are unchanged).
* ``tracer`` — ``SpanTracer``: per-request lifecycle events
  (submit → queue → place → chunk* → evict → complete → poll), JSONL
  export, text timelines, and the zero-span-loss audit
  (``check_complete``).
* ``traffic`` — ``TrafficAccountant``: modeled HBM bytes charged per
  dispatch decision using the ``kernels/ops.py`` dispatch-table formulas,
  plus a roofline bytes-vs-FLOPs summary (``launch/roofline.py``).

``Observability`` bundles the three with one enable switch and one
injected clock. ``enabled=False`` swaps the tracer and accountant for
their null twins — the registry stays live because ``stats()`` depends
on it; counter increments are the part of the overhead budget that is
not optional. The obs-overhead CI job holds the *enabled* path to <= 5%
throughput/p99 overhead over disabled on the scheduler DES.

Per-process aggregation: every ``Observability`` defaults to parenting
its registry and accountant to the process-global bundle
(``get_global()``), mirroring ``ops.dispatch_counters``'s stack idiom —
scheduler-local metrics stay isolated for ``stats()`` while
``benchmarks/run.py`` dumps one ``OBS_<suite>.json`` per suite from the
global and resets it between suites (``reset_global()``). Tracers are
NOT globally merged: rid spaces are per scheduler, so spans live with
their scheduler (``sched.obs.tracer``).
"""
from __future__ import annotations

import time
from typing import Callable

from repro.obs.registry import (Counter, Gauge, Histogram, MetricsRegistry,
                                DEFAULT_COUNT_BUCKETS, DEFAULT_TIME_BUCKETS,
                                geometric_buckets)
from repro.obs.trace import NullTracer, SpanTracer, TERMINAL_STATUSES
from repro.obs.traffic import (NullAccountant, TrafficAccountant,
                               chunk_bytes, cost_source_bytes,
                               gang_collective_bytes, modeled_flops,
                               solve_bytes)

__all__ = [
    "Observability", "MetricsRegistry", "Counter", "Gauge", "Histogram",
    "SpanTracer", "NullTracer", "TrafficAccountant", "NullAccountant",
    "TERMINAL_STATUSES", "DEFAULT_TIME_BUCKETS", "DEFAULT_COUNT_BUCKETS",
    "geometric_buckets", "cost_source_bytes", "solve_bytes", "chunk_bytes",
    "gang_collective_bytes", "modeled_flops", "get_global", "reset_global",
    "global_dump",
]


class Observability:
    """One scheduler's (or one suite's) instrument bundle.

    ``enabled=False`` keeps the registry live (stats' counters must keep
    counting) but swaps tracing and traffic accounting for no-ops.
    ``parent`` defaults to the process-global bundle; pass
    ``parent=None`` explicitly via ``chain=False`` to isolate (tests).
    """

    def __init__(self, *, enabled: bool = True,
                 clock: Callable[[], float] = time.monotonic,
                 chain: bool = True,
                 parent: "Observability | None" = None):
        if parent is None and chain:
            parent = get_global()
        self.enabled = enabled
        self.parent = parent
        self.registry = MetricsRegistry(
            parent=parent.registry if parent is not None else None)
        if enabled:
            self.tracer = SpanTracer(clock=clock)
            self.traffic = TrafficAccountant(
                parent=parent.traffic if parent is not None else None)
        else:
            self.tracer = NullTracer(clock=clock)
            self.traffic = NullAccountant()

    def dump(self) -> dict:
        """Registry + traffic snapshot (the ``OBS_<suite>.json`` payload;
        spans export separately as JSONL via ``tracer.write_jsonl``)."""
        return {"enabled": self.enabled, "registry": self.registry.dump(),
                "traffic": self.traffic.dump()}


class _GlobalObservability(Observability):
    """The process-global aggregation root (no parent, no clock user)."""

    def __init__(self):
        super().__init__(enabled=True, chain=False, parent=None)

    def reset(self) -> None:
        self.registry.reset()
        self.traffic.reset()
        self.tracer.clear()


_GLOBAL: _GlobalObservability | None = None


def get_global() -> _GlobalObservability:
    """The process-global ``Observability`` every child chains to by
    default (created on first use)."""
    global _GLOBAL
    if _GLOBAL is None:
        _GLOBAL = _GlobalObservability()
    return _GLOBAL


def reset_global() -> None:
    """Zero the global registry and accountant (between benchmark suites;
    schedulers built BEFORE a reset keep counting into the old, orphaned
    parent metrics — build them after)."""
    get_global().reset()


def global_dump() -> dict:
    """Snapshot of the process-global bundle."""
    return get_global().dump()
