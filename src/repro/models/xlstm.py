"""xLSTM blocks: mLSTM (matrix memory, chunk-parallel) and sLSTM (scalar
memory, time-recurrent). [arXiv:2405.04517]

Adaptations recorded in DESIGN.md: gates are sigmoid-bounded (the paper's
exp input gate + max-stabilizer is replaced by the numerically-safe bounded
form; the memory/update structure — matrix memory C, normalizer n, output
q.C/max(|q.n|,1) — is faithful). mLSTM uses the shared chunked-GLA core.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.gla import chunked_gla, gla_decode_step
from repro.models.layers import normal_init


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_init(key, d_model, num_heads, head_dim, dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    H, hd = num_heads, head_dim
    return {
        "w_q": normal_init(ks[0], (d_model, H * hd), dtype=dtype),
        "w_k": normal_init(ks[1], (d_model, H * hd), dtype=dtype),
        "w_v": normal_init(ks[2], (d_model, H * hd), dtype=dtype),
        "w_f": normal_init(ks[3], (d_model, H), dtype=jnp.float32),
        "w_i": normal_init(ks[4], (d_model, H), dtype=jnp.float32),
        "w_gate": normal_init(ks[5], (d_model, H * hd), dtype=dtype),
        "w_o": normal_init(jax.random.fold_in(key, 7), (H * hd, d_model),
                           dtype=dtype),
        "f_bias": jnp.full((H,), 3.0, jnp.float32),  # init forget ~ open
    }


def _qkv_gates(params, x, num_heads, head_dim):
    B, T, _ = x.shape
    H, hd = num_heads, head_dim
    q = (x @ params["w_q"]).reshape(B, T, H, hd) / jnp.sqrt(hd).astype(x.dtype)
    k = (x @ params["w_k"]).reshape(B, T, H, hd)
    v = (x @ params["w_v"]).reshape(B, T, H, hd)
    log_f = jax.nn.log_sigmoid(
        x.astype(jnp.float32) @ params["w_f"] + params["f_bias"])
    log_i = jax.nn.log_sigmoid(x.astype(jnp.float32) @ params["w_i"])
    return q, k, v, log_f, log_i


def mlstm_apply(params, x, *, num_heads, head_dim, chunk=64, state=None):
    """x: (B, T, d). Returns (y, (S, n)) — state for seq continuation."""
    B, T, D = x.shape
    q, k, v, log_f, log_i = _qkv_gates(params, x, num_heads, head_dim)
    S0, n0 = (None, None) if state is None else state
    y, S, n = chunked_gla(q, k, v, log_f, log_i, chunk=min(chunk, T),
                          use_norm=True, S0=S0, n0=n0)
    y = y.reshape(B, T, num_heads * head_dim)
    y = y * jax.nn.silu(x @ params["w_gate"])
    return y @ params["w_o"], (S, n)


def mlstm_decode(params, x, state, *, num_heads, head_dim):
    """x: (B, 1, d); state = (S, n). O(1) per token."""
    B, _, D = x.shape
    q, k, v, log_f, log_i = _qkv_gates(params, x, num_heads, head_dim)
    S, n = state
    y, S, n = gla_decode_step(q[:, 0], k[:, 0], v[:, 0], log_f[:, 0],
                              log_i[:, 0], S, n, use_norm=True)
    y = y.reshape(B, 1, num_heads * head_dim)
    y = y * jax.nn.silu(x @ params["w_gate"])
    return y @ params["w_o"], (S, n)


def mlstm_state_init(batch, num_heads, head_dim, dtype=jnp.float32):
    return (jnp.zeros((batch, num_heads, head_dim, head_dim), dtype),
            jnp.zeros((batch, num_heads, head_dim), dtype))


# ---------------------------------------------------------------------------
# sLSTM (true recurrence, block-diagonal per-head recurrent weights)
# ---------------------------------------------------------------------------

def slstm_init(key, d_model, num_heads, head_dim, dtype=jnp.float32):
    ks = jax.random.split(key, 10)
    H, hd = num_heads, head_dim
    p = {"w_o": normal_init(ks[8], (H * hd, d_model), dtype=dtype),
         "f_bias": jnp.full((H, hd), 3.0, jnp.float32)}
    for i, name in enumerate(("z", "i", "f", "o")):
        p[f"w_{name}"] = normal_init(ks[i], (d_model, H * hd), dtype=dtype)
        p[f"r_{name}"] = normal_init(ks[4 + i], (H, hd, hd), scale=0.01,
                                     dtype=jnp.float32)
    return p


def slstm_step(params, x_t, state, num_heads, head_dim):
    """One time step. x_t: (B, d); state = (c, n, h) each (B, H, hd)."""
    c, n, h = state
    B = x_t.shape[0]
    H, hd = num_heads, head_dim

    def gate(name):
        wx = (x_t @ params[f"w_{name}"]).reshape(B, H, hd).astype(jnp.float32)
        rh = jnp.einsum("bhd,hde->bhe", h, params[f"r_{name}"])
        return wx + rh

    z = jnp.tanh(gate("z"))
    i = jax.nn.sigmoid(gate("i"))
    f = jax.nn.sigmoid(gate("f") + params["f_bias"])
    o = jax.nn.sigmoid(gate("o"))
    c = f * c + i * z
    n = f * n + i
    h = o * (c / jnp.maximum(n, 1.0))
    return (c, n, h)


def slstm_apply(params, x, *, num_heads, head_dim, state=None):
    """x: (B, T, d) — lax.scan over time (inherently sequential)."""
    B, T, D = x.shape
    H, hd = num_heads, head_dim
    if state is None:
        state = slstm_state_init(B, H, hd)

    def body(carry, x_t):
        carry = slstm_step(params, x_t, carry, H, hd)
        return carry, carry[2]  # emit h

    state, hs = jax.lax.scan(body, state, x.transpose(1, 0, 2))
    y = hs.transpose(1, 0, 2, 3).reshape(B, T, H * hd).astype(x.dtype)
    return y @ params["w_o"], state


def slstm_decode(params, x, state, *, num_heads, head_dim):
    B = x.shape[0]
    state = slstm_step(params, x[:, 0], state, num_heads, head_dim)
    y = state[2].reshape(B, 1, num_heads * head_dim).astype(x.dtype)
    return y @ params["w_o"], state


def slstm_state_init(batch, num_heads, head_dim):
    z = jnp.zeros((batch, num_heads, head_dim), jnp.float32)
    return (z, z, z)
