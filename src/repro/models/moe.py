"""Mixture-of-Experts layer with top-k or Sinkhorn/UOT routing.

The ``sinkhorn`` router is the framework integration point for the paper:
expert assignment is an unbalanced optimal transport problem between tokens
(row marginal: each token carries top_k units of mass) and experts (column
marginal: equal capacity). A few MAP-UOT fused iterations
(repro.core.sinkhorn_fused.fused_iteration — single-pass schedule) balance
the routing matrix; the unbalanced relaxation (fi < 1) tolerates residual
imbalance instead of forcing hard balance like BASE layers. Gradients flow
through the softmax gates (straight-through on the plan), the standard
Sinkhorn-router trick.

Dispatch is capacity-based sort-scatter (MegaBlocks/MaxText style): tokens
are ranked within their expert via argsort, dropped beyond capacity,
scattered into an (E, C, d) buffer, processed with batched expert matmuls
(MXU-friendly, EP-shardable on the "model" axis), and combined back.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.problem import rescale_factors
from repro.core.sinkhorn_fused import fused_iteration
from repro.models.layers import normal_init


def moe_init(key, d_model, d_ff, num_experts, dtype=jnp.float32):
    kr, kg, ku, kd = jax.random.split(key, 4)
    return {
        "w_router": normal_init(kr, (d_model, num_experts), dtype=jnp.float32),
        "w_gate": normal_init(kg, (num_experts, d_model, d_ff), dtype=dtype),
        "w_up": normal_init(ku, (num_experts, d_model, d_ff), dtype=dtype),
        "w_down": normal_init(kd, (num_experts, d_ff, d_model), dtype=dtype),
    }


def _positions_within_expert(flat_e, num_experts):
    """Rank of each assignment within its expert (sort-based, O(n log n))."""
    n = flat_e.shape[0]
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    idx = jnp.arange(n)
    is_start = jnp.concatenate([jnp.array([True]), sorted_e[1:] != sorted_e[:-1]])
    group_start = jax.lax.associative_scan(jnp.maximum,
                                           jnp.where(is_start, idx, 0))
    pos_sorted = idx - group_start
    pos = jnp.zeros((n,), jnp.int32).at[order].set(pos_sorted.astype(jnp.int32))
    return pos


def sinkhorn_route(logits, top_k, *, num_iters=4, fi=0.7, temp=1.0,
                   use_pallas=False):
    """UOT-balanced routing plan. logits: (T, E). Returns (T, E) plan.

    Row marginal: top_k per token; column marginal: T*top_k/E per expert
    (uniform capacity). fi < 1 relaxes both — tokens with no confident
    expert may send less mass, hot experts may keep more than fair share.

    use_pallas: run the MAP-UOT fused Pallas kernel (single HBM pass per
    iteration) instead of the jnp form — for real-TPU serving/training;
    interpret-mode on CPU (tests assert equality), OFF in dry-runs (the
    TPU mosaic lowering does not exist on the CPU backend).
    """
    T, E = logits.shape
    # Gibbs kernel from router affinities (stabilized).
    A = jnp.exp((logits - jax.lax.stop_gradient(logits.max(-1, keepdims=True)))
                / temp).astype(jnp.float32)
    a = jnp.full((T,), float(top_k), jnp.float32)
    b = jnp.full((E,), 0.0, jnp.float32) + (T * top_k / E)

    if use_pallas:
        from repro.core.problem import UOTConfig
        from repro.kernels import ops
        cfg = UOTConfig(num_iters=num_iters, reg=1.0,
                        reg_m=fi / (1.0 - fi) if fi < 1 else float("inf"))
        A_out, _ = ops.solve_fused(A, a, b, cfg)
        return A_out

    colsum = A.sum(axis=0)

    def body(_, carry):
        A, colsum = carry
        fcol = rescale_factors(b, colsum, fi)
        A = A * fcol[None, :]
        rowsum = A.sum(axis=1)
        frow = rescale_factors(a, rowsum, fi)
        A = A * frow[:, None]
        return A, A.sum(axis=0)

    A, _ = jax.lax.fori_loop(0, num_iters, body, (A, colsum))
    return A


def route(params, x_tok, *, top_k, router="topk", sinkhorn_iters=4,
          sinkhorn_fi=0.7):
    """Select experts. x_tok: (T, d). Returns (weights (T,k), ids (T,k), aux).

    aux = Switch-style load-balance loss (fraction_e * mean_gate_e * E).
    """
    logits = (x_tok.astype(jnp.float32) @ params["w_router"])
    gates = jax.nn.softmax(logits, axis=-1)                      # (T, E)
    T, E = gates.shape

    if router == "sinkhorn":
        plan = sinkhorn_route(logits, top_k, num_iters=sinkhorn_iters,
                              fi=sinkhorn_fi)
        # plan picks the experts (stop-grad); gates carry the gradient.
        sel = jax.lax.stop_gradient(plan)
    elif router == "topk":
        sel = gates
    else:
        raise ValueError(router)

    _, ids = jax.lax.top_k(sel, top_k)                           # (T, k)
    w = jnp.take_along_axis(gates, ids, axis=1)
    w = w / jnp.maximum(w.sum(axis=1, keepdims=True), 1e-9)      # renormalize

    # load-balance aux loss over the *chosen* assignment
    onehot = jax.nn.one_hot(ids, E, dtype=jnp.float32)           # (T, k, E)
    frac = onehot.sum(axis=(0, 1)) / (T * top_k)
    mean_gate = gates.mean(axis=0)
    aux = E * jnp.sum(frac * mean_gate)
    return w.astype(x_tok.dtype), ids, aux


def moe_apply(params, x, *, top_k, capacity_factor=1.25, router="topk",
              sinkhorn_iters=4, sinkhorn_fi=0.7, dbg=False):
    """x: (B, S, d) -> (y, aux_loss)."""
    B, S, D = x.shape
    T = B * S
    E = params["w_router"].shape[1]
    x_tok = x.reshape(T, D)

    w, ids, aux = route(params, x_tok, top_k=top_k, router=router,
                        sinkhorn_iters=sinkhorn_iters, sinkhorn_fi=sinkhorn_fi)

    C = int(max(1, round(T * top_k * capacity_factor / E)))
    flat_e = ids.reshape(-1)                                     # (T*k,)
    pos = _positions_within_expert(flat_e, E)                    # (T*k,)
    keep = pos < C
    slot = jnp.where(keep, flat_e * C + pos, E * C)              # dump slot

    # dispatch: (E*C+1, d) buffer, slot-unique scatter
    xk = jnp.repeat(x_tok, top_k, axis=0)                        # (T*k, d)
    buf = jnp.zeros((E * C + 1, D), x.dtype).at[slot].set(xk)
    ebuf = buf[:E * C].reshape(E, C, D)

    # expert SwiGLU (batched over experts -> EP shardable)
    gate = jax.nn.silu(jnp.einsum("ecd,edf->ecf", ebuf, params["w_gate"]))
    up = jnp.einsum("ecd,edf->ecf", ebuf, params["w_up"])
    eout = jnp.einsum("ecf,efd->ecd", gate * up, params["w_down"])

    # combine: gather back + weighted sum over the k assignments
    flat_out = jnp.concatenate(
        [eout.reshape(E * C, D), jnp.zeros((1, D), eout.dtype)], axis=0)
    tok_out = flat_out[slot].reshape(T, top_k, D)
    y = jnp.einsum("tk,tkd->td", w.astype(jnp.float32),
                   tok_out.astype(jnp.float32)).astype(x.dtype)
    out = y.reshape(B, S, D)
    if dbg:
        return out, aux, {"ids": ids, "w": w, "keep": keep.reshape(T, top_k)}
    return out, aux


def moe_apply_dense_ref(params, x, *, top_k, router="topk",
                        sinkhorn_iters=4, sinkhorn_fi=0.7):
    """No-capacity dense reference (loops over experts) for tests."""
    B, S, D = x.shape
    T = B * S
    x_tok = x.reshape(T, D)
    w, ids, aux = route(params, x_tok, top_k=top_k, router=router,
                        sinkhorn_iters=sinkhorn_iters, sinkhorn_fi=sinkhorn_fi)
    E = params["w_router"].shape[1]
    y = jnp.zeros((T, D), jnp.float32)
    for e in range(E):
        gate = jax.nn.silu(x_tok @ params["w_gate"][e])
        up = x_tok @ params["w_up"][e]
        out_e = (gate * up) @ params["w_down"][e]
        m = (ids == e).astype(jnp.float32) * w.astype(jnp.float32)  # (T, k)
        y = y + m.sum(axis=1)[:, None] * out_e.astype(jnp.float32)
    return y.reshape(B, S, D).astype(x.dtype), aux
