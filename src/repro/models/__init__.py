"""Model zoo substrate: dense GQA transformers, MoE (with Sinkhorn-UOT
router), xLSTM, Mamba2 hybrids, VLM/audio backbones — pure functional JAX
(param pytrees + apply fns), scan-over-layers + remat for compile scale."""
