"""Residual blocks: init + apply for each block type (dense/moe/ssm/hybrid)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import layers as L
from repro.models import mamba2 as mb
from repro.models import moe as moe_mod
from repro.models import xlstm as xl


# --- dense / moe transformer block -----------------------------------------

def dense_block_init(key, cfg):
    k1, k2 = jax.random.split(key)
    return {
        "norm1": L.rmsnorm_init(cfg.d_model),
        "attn": attn.attention_init(k1, cfg.d_model, cfg.num_heads,
                                    cfg.num_kv_heads, cfg.hd),
        "norm2": L.rmsnorm_init(cfg.d_model),
        "mlp": L.mlp_init(k2, cfg.d_model, cfg.d_ff, gated=cfg.mlp_gated),
    }


def moe_block_init(key, cfg):
    k1, k2 = jax.random.split(key)
    return {
        "norm1": L.rmsnorm_init(cfg.d_model),
        "attn": attn.attention_init(k1, cfg.d_model, cfg.num_heads,
                                    cfg.num_kv_heads, cfg.hd),
        "norm2": L.rmsnorm_init(cfg.d_model),
        "moe": moe_mod.moe_init(k2, cfg.d_model, cfg.d_ff, cfg.num_experts),
    }


def _attn_kw(cfg, window=None, full=True):
    kw = dict(num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
              head_dim=cfg.hd, rope_theta=cfg.rope_theta,
              window=cfg.sliding_window if window is None else window)
    if full:  # full-sequence paths also choose the attention impl
        kw.update(impl=cfg.attn_impl, q_chunk=cfg.attn_q_chunk,
                  kv_chunk=cfg.attn_kv_chunk, unroll=not cfg.scan_layers)
    return kw


def dense_block_apply(params, x, cfg):
    h, _ = attn.attention_apply(params["attn"],
                                L.rmsnorm(params["norm1"], x, cfg.norm_eps),
                                **_attn_kw(cfg, window=0))
    x = x + h
    x = x + L.mlp_apply(params["mlp"],
                        L.rmsnorm(params["norm2"], x, cfg.norm_eps))
    return x, jnp.float32(0.0)


def moe_block_apply(params, x, cfg):
    h, _ = attn.attention_apply(params["attn"],
                                L.rmsnorm(params["norm1"], x, cfg.norm_eps),
                                **_attn_kw(cfg, window=0))
    x = x + h
    h, aux = moe_mod.moe_apply(
        params["moe"], L.rmsnorm(params["norm2"], x, cfg.norm_eps),
        top_k=cfg.top_k, capacity_factor=cfg.capacity_factor,
        router=cfg.router, sinkhorn_iters=cfg.sinkhorn_iters,
        sinkhorn_fi=cfg.sinkhorn_fi)
    return x + h, aux


def dense_block_decode(params, x, cache, index, cfg, window=0):
    h, cache = attn.attention_decode(
        params["attn"], L.rmsnorm(params["norm1"], x, cfg.norm_eps),
        cache, index, **_attn_kw(cfg, window=window, full=False))
    x = x + h
    x = x + L.mlp_apply(params["mlp"],
                        L.rmsnorm(params["norm2"], x, cfg.norm_eps))
    return x, cache


def moe_block_decode(params, x, cache, index, cfg):
    h, cache = attn.attention_decode(
        params["attn"], L.rmsnorm(params["norm1"], x, cfg.norm_eps),
        cache, index, **_attn_kw(cfg, window=0, full=False))
    x = x + h
    # Decode always routes by plain top-k gates: Sinkhorn balancing is a
    # population-level construct (the plan depends on the whole token batch)
    # and is a training/prefill-time concern; single-token decode with it
    # would make logits depend on unrelated requests in the batch.
    h, _ = moe_mod.moe_apply(
        params["moe"], L.rmsnorm(params["norm2"], x, cfg.norm_eps),
        top_k=cfg.top_k, capacity_factor=cfg.capacity_factor,
        router="topk")
    return x + h, cache


# --- xlstm blocks ------------------------------------------------------------

def mlstm_block_init(key, cfg):
    return {"norm": L.rmsnorm_init(cfg.d_model),
            "mlstm": xl.mlstm_init(key, cfg.d_model, cfg.num_heads, cfg.hd)}


def slstm_block_init(key, cfg):
    return {"norm": L.rmsnorm_init(cfg.d_model),
            "slstm": xl.slstm_init(key, cfg.d_model, cfg.num_heads, cfg.hd)}


def mlstm_block_apply(params, x, cfg, state=None):
    h, state = xl.mlstm_apply(params["mlstm"],
                              L.rmsnorm(params["norm"], x, cfg.norm_eps),
                              num_heads=cfg.num_heads, head_dim=cfg.hd,
                              chunk=cfg.gla_chunk, state=state)
    return x + h, state


def slstm_block_apply(params, x, cfg, state=None):
    h, state = xl.slstm_apply(params["slstm"],
                              L.rmsnorm(params["norm"], x, cfg.norm_eps),
                              num_heads=cfg.num_heads, head_dim=cfg.hd,
                              state=state)
    return x + h, state


def mlstm_block_decode(params, x, state, cfg):
    h, state = xl.mlstm_decode(params["mlstm"],
                               L.rmsnorm(params["norm"], x, cfg.norm_eps),
                               state, num_heads=cfg.num_heads, head_dim=cfg.hd)
    return x + h, state


def slstm_block_decode(params, x, state, cfg):
    h, state = xl.slstm_decode(params["slstm"],
                               L.rmsnorm(params["norm"], x, cfg.norm_eps),
                               state, num_heads=cfg.num_heads, head_dim=cfg.hd)
    return x + h, state


# --- mamba2 block (zamba2 hybrid) -------------------------------------------

def mamba_block_init(key, cfg):
    return {"norm": L.rmsnorm_init(cfg.d_model),
            "mamba": mb.mamba2_init(key, cfg.d_model, cfg.ssm_state,
                                    cfg.ssm_heads, cfg.ssm_head_dim)}


def mamba_block_apply(params, x, cfg, state=None):
    h, state = mb.mamba2_apply(params["mamba"],
                               L.rmsnorm(params["norm"], x, cfg.norm_eps),
                               num_heads=cfg.ssm_heads,
                               head_dim=cfg.ssm_head_dim,
                               d_state=cfg.ssm_state, chunk=cfg.gla_chunk,
                               state=state)
    return x + h, state


def mamba_block_decode(params, x, state, cfg):
    h, state = mb.mamba2_decode(params["mamba"],
                                L.rmsnorm(params["norm"], x, cfg.norm_eps),
                                state, num_heads=cfg.ssm_heads,
                                head_dim=cfg.ssm_head_dim,
                                d_state=cfg.ssm_state)
    return x + h, state
