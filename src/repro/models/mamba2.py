"""Mamba2 (SSD) block [arXiv:2405.21060] on the shared chunked-GLA core.

Mapping onto the GLA recurrence (state S: (d_state, head_dim) per head):
    decay g_t = exp(-dt_t * exp(A_log))    (scalar per head per step)
    k_t  = B_t      (d_state, shared across heads: n_groups=1)
    v_t  = dt_t * x_t                      (head inputs, ZOH-discretized)
    q_t  = C_t      (d_state)
    y_t  = q_t @ S_t + D * x_t             (skip connection)
Plus the Mamba front-end: causal depthwise conv (width 4) + SiLU on the
x/B/C stream, and an output SiLU gate z. Decode carries (conv tail, S).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.gla import chunked_gla, gla_decode_step
from repro.models.layers import normal_init

CONV_W = 4


def mamba2_init(key, d_model, d_state, num_heads, head_dim,
                dtype=jnp.float32):
    ks = jax.random.split(key, 8)
    d_inner = num_heads * head_dim
    conv_dim = d_inner + 2 * d_state
    return {
        "w_z": normal_init(ks[0], (d_model, d_inner), dtype=dtype),
        "w_xbc": normal_init(ks[1], (d_model, conv_dim), dtype=dtype),
        "conv_k": normal_init(ks[2], (CONV_W, conv_dim), scale=0.5,
                              dtype=jnp.float32),
        "w_dt": normal_init(ks[3], (d_model, num_heads), dtype=jnp.float32),
        "dt_bias": jnp.zeros((num_heads,), jnp.float32),
        "A_log": jnp.zeros((num_heads,), jnp.float32),
        "D": jnp.ones((num_heads,), jnp.float32),
        "w_o": normal_init(ks[4], (d_inner, d_model), dtype=dtype),
    }


def _causal_conv(xbc, conv_k, tail=None):
    """Depthwise causal conv, width CONV_W. xbc: (B, T, C).

    tail: (B, CONV_W-1, C) previous inputs for decode continuity (or zeros).
    Returns (y, new_tail)."""
    B, T, C = xbc.shape
    if tail is None:
        tail = jnp.zeros((B, CONV_W - 1, C), xbc.dtype)
    xp = jnp.concatenate([tail.astype(xbc.dtype), xbc], axis=1)  # (B,T+3,C)
    y = sum(xp[:, i:i + T, :] * conv_k[i][None, None, :]
            for i in range(CONV_W))
    new_tail = xp[:, T:T + CONV_W - 1, :]
    return y, new_tail


def _front(params, x, num_heads, head_dim, d_state, conv_tail=None):
    B, T, _ = x.shape
    d_inner = num_heads * head_dim
    z = x @ params["w_z"]
    xbc = x @ params["w_xbc"]
    xbc, new_tail = _causal_conv(xbc, params["conv_k"], conv_tail)
    xbc = jax.nn.silu(xbc)
    xs = xbc[..., :d_inner].reshape(B, T, num_heads, head_dim)
    Bm = xbc[..., d_inner:d_inner + d_state]            # (B, T, d_state)
    Cm = xbc[..., d_inner + d_state:]
    dt = jax.nn.softplus(x.astype(jnp.float32) @ params["w_dt"]
                         + params["dt_bias"])           # (B, T, H)
    log_g = -dt * jnp.exp(params["A_log"])              # <= 0
    k = jnp.broadcast_to(Bm[:, :, None, :], (B, T, num_heads, d_state))
    q = jnp.broadcast_to(Cm[:, :, None, :], (B, T, num_heads, d_state))
    v = xs * dt[..., None].astype(xs.dtype)
    return z, xs, q, k, v, log_g, new_tail


def mamba2_apply(params, x, *, num_heads, head_dim, d_state, chunk=64,
                 state=None):
    """x: (B, T, d) -> (y, (S, conv_tail))."""
    B, T, D = x.shape
    S0, tail0 = (None, None) if state is None else state
    z, xs, q, k, v, log_g, tail = _front(params, x, num_heads, head_dim,
                                         d_state, tail0)
    log_i = jnp.zeros_like(log_g)  # input weight folded into v (dt * x)
    y, S, _ = chunked_gla(q, k, v, log_g, log_i, chunk=min(chunk, T),
                          use_norm=False, S0=S0)
    y = y + params["D"][None, None, :, None].astype(y.dtype) * xs
    y = y.reshape(B, T, num_heads * head_dim)
    y = y * jax.nn.silu(z)
    return y @ params["w_o"], (S, tail)


def mamba2_decode(params, x, state, *, num_heads, head_dim, d_state):
    """x: (B, 1, d); state = (S, conv_tail). O(1) per token."""
    B = x.shape[0]
    S, tail = state
    z, xs, q, k, v, log_g, tail = _front(params, x, num_heads, head_dim,
                                         d_state, tail)
    y, S, _ = gla_decode_step(q[:, 0], k[:, 0], v[:, 0], log_g[:, 0],
                              jnp.zeros_like(log_g[:, 0]), S,
                              jnp.zeros((B, num_heads, d_state), jnp.float32),
                              use_norm=False)
    y = y + params["D"][None, :, None].astype(y.dtype) * xs[:, 0]
    y = y.reshape(B, 1, num_heads * head_dim)
    y = y * jax.nn.silu(z)
    return y @ params["w_o"], (S, tail)


def mamba2_state_init(batch, num_heads, head_dim, d_state, d_model=None,
                      dtype=jnp.float32):
    d_inner = num_heads * head_dim
    conv_dim = d_inner + 2 * d_state
    return (jnp.zeros((batch, num_heads, d_state, head_dim), dtype),
            jnp.zeros((batch, CONV_W - 1, conv_dim), dtype))
