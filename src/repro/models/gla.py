"""Chunked gated linear attention (GLA) core.

One well-tested primitive serves both SSM-family archs:
  * mLSTM (xlstm)  — q/k/v heads, scalar sigmoid forget+input gates,
    normalizer state n (out = q.S / max(|q.n|, 1)).
  * Mamba2 (zamba2) — q=C, k=B, v=dt*x, decay=exp(-dt*A), no normalizer.

Recurrence per head (state S: (dk, dv), normalizer n: (dk,)):
    S_t = g_t * S_{t-1} + i_t * k_t (x) v_t
    n_t = g_t * n_{t-1} + i_t * k_t
    y_t = q_t @ S_t            [/ max(|q_t . n_t|, 1) if use_norm]

Training uses the chunkwise parallel form (intra-chunk quadratic +
inter-chunk state passing) — O(T/L) sequential steps, MXU-friendly (L x L)
and (dk x dv) matmuls; decode is the O(1)-per-token recurrent step, which is
what makes the `long_500k` cells constant-memory for SSM archs.

Gates are sigmoid-bounded so all within-chunk exponentials are of
non-positive numbers (numerically safe without a max-stabilizer).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def chunked_gla(q, k, v, log_g, log_i, *, chunk: int, use_norm: bool,
                S0=None, n0=None):
    """q,k: (B, T, H, dk); v: (B, T, H, dv); log_g, log_i: (B, T, H) <= 0.

    Returns (y (B, T, H, dv), S_T (B, H, dk, dv), n_T (B, H, dk)).
    """
    B, T, H, dk = q.shape
    dv = v.shape[-1]
    T0 = T
    pad = (-T) % chunk
    if pad:
        # pad with inert steps: i=0 (no state write), g=1 (no decay) — the
        # carried state and the real positions' outputs are unaffected.
        zpad = lambda x: jnp.pad(x, ((0, 0), (0, pad)) + ((0, 0),) * (x.ndim - 2))
        q, k, v = zpad(q), zpad(k), zpad(v)
        log_g = zpad(log_g)
        log_i = jnp.pad(log_i, ((0, 0), (0, pad), (0, 0)),
                        constant_values=-1e9)
        T = T + pad
    nC = T // chunk
    f32 = jnp.float32

    # (B, H, nC, L, d)
    def to_chunks(x, d):
        return x.transpose(0, 2, 1, 3).reshape(B, H, nC, chunk, d)

    qc = to_chunks(q.astype(f32), dk)
    kc = to_chunks(k.astype(f32), dk)
    vc = to_chunks(v.astype(f32), dv)
    lg = log_g.astype(f32).transpose(0, 2, 1).reshape(B, H, nC, chunk)
    li = log_i.astype(f32).transpose(0, 2, 1).reshape(B, H, nC, chunk)

    cum = jnp.cumsum(lg, axis=-1)                       # inclusive decay
    # intra-chunk pairwise weights w[t, s] = exp(cum_t - cum_s + li_s), s <= t
    wts = cum[..., :, None] - cum[..., None, :] + li[..., None, :]
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    wts = jnp.where(mask, jnp.exp(wts), 0.0)            # (B,H,nC,L,L)
    # carry-in decays / chunk-end weights
    dq = jnp.exp(cum)                                   # (B,H,nC,L)
    tail = jnp.exp(cum[..., -1:] - cum + li)            # weight into S_new
    gall = jnp.exp(cum[..., -1])                        # chunk total decay

    scores = jnp.einsum("bhctd,bhcsd->bhcts", qc, kc)   # (B,H,nC,L,L)
    sw = scores * wts

    S0 = jnp.zeros((B, H, dk, dv), f32) if S0 is None else S0.astype(f32)
    n0 = jnp.zeros((B, H, dk), f32) if n0 is None else n0.astype(f32)

    def body(carry, inp):
        S, n = carry
        q_, k_, v_, sw_, dq_, tail_, g_ = inp
        # inter-chunk: decayed contribution of carried state
        inter = jnp.einsum("bhtd,bhde->bhte", q_, S) * dq_[..., None]
        intra = jnp.einsum("bhts,bhse->bhte", sw_, v_)
        y = inter + intra
        if use_norm:
            qn_inter = jnp.einsum("bhtd,bhd->bht", q_, n) * dq_
            qn_intra = jnp.sum(sw_, axis=-1)  # == (scores*w) @ 1 when k.q? no:
            # normalizer uses k only: q.n_t = sum_s w_ts (q_t.k_s) -> that IS
            # sw row-sum ONLY if scores were q.k — they are. Reuse sw.
            qn = qn_inter + qn_intra
            y = y / jnp.maximum(jnp.abs(qn)[..., None], 1.0)
        S = (g_[..., None, None] * S
             + jnp.einsum("bht,bhtd,bhte->bhde", tail_, k_, v_))
        n = g_[..., None] * n + jnp.einsum("bht,bhtd->bhd", tail_, k_)
        return (S, n), y

    # scan over chunks (axis 2)
    xs = (qc.transpose(2, 0, 1, 3, 4), kc.transpose(2, 0, 1, 3, 4),
          vc.transpose(2, 0, 1, 3, 4), sw.transpose(2, 0, 1, 3, 4),
          dq.transpose(2, 0, 1, 3), tail.transpose(2, 0, 1, 3),
          gall.transpose(2, 0, 1))
    (S, n), ys = jax.lax.scan(body, (S0, n0), xs)
    # ys: (nC, B, H, L, dv) -> (B, nC*L, H, dv)
    y = ys.transpose(1, 0, 3, 2, 4).reshape(B, T, H, dv)
    return y[:, :T0].astype(v.dtype), S, n


def serial_gla(q, k, v, log_g, log_i, *, use_norm: bool, S0=None, n0=None):
    """Step-by-step oracle for chunked_gla (tests only)."""
    B, T, H, dk = q.shape
    dv = v.shape[-1]
    f32 = jnp.float32
    S = jnp.zeros((B, H, dk, dv), f32) if S0 is None else S0.astype(f32)
    n = jnp.zeros((B, H, dk), f32) if n0 is None else n0.astype(f32)

    def step(carry, inp):
        S, n = carry
        q_, k_, v_, g_, i_ = inp  # (B,H,d...) , gates (B,H)
        S = g_[..., None, None] * S + i_[..., None, None] * (
            k_[..., :, None] * v_[..., None, :])
        n = g_[..., None] * n + i_[..., None] * k_
        y = jnp.einsum("bhd,bhde->bhe", q_, S)
        if use_norm:
            qn = jnp.einsum("bhd,bhd->bh", q_, n)
            y = y / jnp.maximum(jnp.abs(qn)[..., None], 1.0)
        return (S, n), y

    xs = (q.astype(f32).transpose(1, 0, 2, 3), k.astype(f32).transpose(1, 0, 2, 3),
          v.astype(f32).transpose(1, 0, 2, 3),
          jnp.exp(log_g.astype(f32)).transpose(1, 0, 2),
          jnp.exp(log_i.astype(f32)).transpose(1, 0, 2))
    (S, n), ys = jax.lax.scan(step, (S, n), xs)
    return ys.transpose(1, 0, 2, 3).astype(v.dtype), S, n


def gla_decode_step(q, k, v, log_g, log_i, S, n, *, use_norm: bool):
    """One recurrent step. q,k: (B,H,dk); v: (B,H,dv); gates (B,H)."""
    f32 = jnp.float32
    g = jnp.exp(log_g.astype(f32))
    i = jnp.exp(log_i.astype(f32))
    S = g[..., None, None] * S + i[..., None, None] * (
        k.astype(f32)[..., :, None] * v.astype(f32)[..., None, :])
    n = g[..., None] * n + i[..., None] * k.astype(f32)
    y = jnp.einsum("bhd,bhde->bhe", q.astype(f32), S)
    if use_norm:
        qn = jnp.einsum("bhd,bhd->bh", q.astype(f32), n)
        y = y / jnp.maximum(jnp.abs(qn)[..., None], 1.0)
    return y.astype(v.dtype), S, n
