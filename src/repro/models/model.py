"""Model assembly: stacks blocks per family, scan-over-layers + remat.

Public surface:
    m = build_model(cfg)
    params = m.init(key)                      # fp32 master pytree
    loss, metrics = m.forward(params, batch)  # train-mode full-seq
    last_logits, cache = m.prefill(params, batch)
    logits, cache = m.decode_step(params, cache, tokens, index)
    cache = m.init_cache(batch, cache_len)    # zeros (dry-run shardable)

Batch layouts (all int32 tokens):
    dense/moe/ssm/hybrid: {"tokens": (B,S), "labels": (B,S)}
    vlm:   {"tokens": (B,S_txt), "image_embeds": (B,n_img,d), "labels": ...}
    audio: {"tokens": (B,K,S), "labels": (B,K,S)}
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import blocks as B
from repro.models import layers as L
from repro.models import mamba2 as mb
from repro.models import moe as moe_mod
from repro.parallel.sharding import constrain

PyTree = Any


def _stack_init(block_init, cfg, key, n):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: block_init(k, cfg))(keys)


def cast_floats(tree, dtype):
    """Cast all floating leaves (master params are fp32; compute in bf16)."""
    def cast(x):
        if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x
    return jax.tree.map(cast, tree)


_REMAT_POLICIES = {
    "nothing": lambda: jax.checkpoint_policies.nothing_saveable,
    "dots": lambda: jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
}


def _maybe_remat(fn, cfg):
    if cfg.remat:
        policy = _REMAT_POLICIES[cfg.remat_policy]()
        return jax.checkpoint(fn, policy=policy)
    return fn


def _index_tree(tree, i):
    return jax.tree.map(lambda p: p[i], tree)


def _stack_trees(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def _scan_or_unroll(body, carry, xs, cfg, length=None):
    """lax.scan when cfg.scan_layers (compact HLO, fast compile) else an
    unrolled python loop (accurate cost_analysis — XLA counts while bodies
    once). Semantics identical; body must be (carry, x) -> (carry, y)."""
    if cfg.scan_layers:
        return jax.lax.scan(body, carry, xs, length=length)
    n = length if length is not None else jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        x_i = None if xs is None else _index_tree(xs, i)
        carry, y = body(carry, x_i)
        ys.append(y)
    if ys and ys[0] is not None:
        return carry, _stack_trees(ys)
    return carry, None


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.compute_dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32

    # ------------------------------------------------------------------ init

    def init(self, key) -> PyTree:
        cfg = self.cfg
        ks = jax.random.split(key, 8)
        params = {"embed": L.embedding_init(ks[0], cfg.padded_vocab, cfg.d_model),
                  "final_norm": L.rmsnorm_init(cfg.d_model)}
        if cfg.family == "audio":
            heads = jax.vmap(
                lambda k: L.output_head_init(k, cfg.d_model, cfg.padded_vocab)
            )(jax.random.split(ks[1], cfg.num_codebooks))
            params["head"] = heads          # (K, d, V)
        else:
            params["head"] = L.output_head_init(ks[1], cfg.d_model,
                                                cfg.padded_vocab)

        fam = cfg.family
        if fam in ("dense", "vlm", "audio"):
            params["layers"] = _stack_init(B.dense_block_init, cfg, ks[2],
                                           cfg.num_layers)
        elif fam == "moe":
            params["layers"] = _stack_init(B.moe_block_init, cfg, ks[2],
                                           cfg.num_layers)
        elif fam == "ssm":
            cyc = cfg.num_layers // cfg.slstm_every
            m = cfg.slstm_every - 1
            k_m, k_s = jax.random.split(ks[2])
            params["mlstm"] = jax.vmap(
                lambda kk: _stack_init(B.mlstm_block_init, cfg, kk, m)
            )(jax.random.split(k_m, cyc))                      # (cyc, m, ...)
            params["slstm"] = _stack_init(B.slstm_block_init, cfg, k_s, cyc)
        elif fam == "hybrid":
            cyc = cfg.num_layers // cfg.attn_every
            tail = cfg.num_layers - cyc * cfg.attn_every
            k_m, k_t, k_a = jax.random.split(ks[2], 3)
            params["mamba"] = jax.vmap(
                lambda kk: _stack_init(B.mamba_block_init, cfg, kk,
                                       cfg.attn_every)
            )(jax.random.split(k_m, cyc))                      # (cyc, 6, ...)
            if tail:
                params["mamba_tail"] = _stack_init(B.mamba_block_init, cfg,
                                                   k_t, tail)
            params["shared_attn"] = B.dense_block_init(k_a, cfg)  # SHARED
        else:
            raise ValueError(fam)
        return params

    # ------------------------------------------------------------- embedding

    def _embed_batch(self, params, batch):
        cfg = self.cfg
        if cfg.family == "vlm":
            tok = L.embed(params["embed"], batch["tokens"])
            img = batch["image_embeds"].astype(tok.dtype)
            return jnp.concatenate([img, tok], axis=1)
        if cfg.family == "audio":
            # sum the K codebook embeddings (shared table)
            embs = L.embed(params["embed"], batch["tokens"])  # (B,K,S,d)
            return embs.sum(axis=1)
        return L.embed(params["embed"], batch["tokens"])

    # ----------------------------------------------------------------- stack

    def _run_stack(self, params, x):
        """Full-sequence stack. Returns (x, aux_loss)."""
        cfg = self.cfg
        fam = cfg.family
        x = x.astype(self.compute_dtype)

        if fam in ("dense", "vlm", "audio", "moe"):
            apply = B.moe_block_apply if fam == "moe" else B.dense_block_apply

            def body(h, layer_params):
                h, aux = apply(layer_params, h, cfg)
                return constrain(h, "carry"), aux

            x, auxs = _scan_or_unroll(_maybe_remat(body, cfg), x,
                                      params["layers"], cfg)
            return x, auxs.mean()

        if fam == "ssm":
            def cycle(h, cyc_params):
                ml, sl = cyc_params

                def inner(h2, mp):
                    h2, _ = B.mlstm_block_apply(mp, h2, cfg)
                    return h2, None

                h, _ = _scan_or_unroll(inner, h, ml, cfg)
                h, _ = B.slstm_block_apply(sl, h, cfg)
                return constrain(h, "carry"), None

            x, _ = _scan_or_unroll(_maybe_remat(cycle, cfg), x,
                                   (params["mlstm"], params["slstm"]), cfg)
            return x, jnp.float32(0.0)

        if fam == "hybrid":
            shared = params["shared_attn"]

            def cycle(h, cyc_params):
                def inner(h2, mp):
                    h2, _ = B.mamba_block_apply(mp, h2, cfg)
                    return h2, None

                h, _ = _scan_or_unroll(inner, h, cyc_params, cfg)
                h, _ = B.dense_block_apply(shared, h, cfg)
                return constrain(h, "carry"), None

            x, _ = _scan_or_unroll(_maybe_remat(cycle, cfg), x,
                                   params["mamba"], cfg)
            if "mamba_tail" in params:
                def tail(h, mp):
                    h, _ = B.mamba_block_apply(mp, h, cfg)
                    return h, None
                x, _ = _scan_or_unroll(_maybe_remat(tail, cfg), x,
                                       params["mamba_tail"], cfg)
            return x, jnp.float32(0.0)

        raise ValueError(fam)

    # --------------------------------------------------------------- forward

    def forward(self, params, batch):
        """Training loss (chunked xent, never materializes full logits)."""
        cfg = self.cfg
        params = cast_floats(params, self.compute_dtype)
        x = self._embed_batch(params, batch)
        x, aux = self._run_stack(params, x)
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)

        if cfg.family == "vlm":
            n_img = batch["image_embeds"].shape[1]
            x = x[:, n_img:, :]

        if cfg.family == "audio":
            loss = self._audio_loss(params, x, batch["labels"])
        else:
            loss = L.chunked_softmax_xent(params["head"], x, batch["labels"],
                                          cfg.vocab_size,
                                          num_chunks=cfg.loss_chunks,
                                          matmul_f32=(cfg.loss_matmul_dtype
                                                      == "f32"))
        metrics = {"xent": loss, "aux": aux}
        if cfg.family == "moe":
            loss = loss + 0.01 * aux
        return loss, metrics

    def _audio_loss(self, params, x, labels):
        """Per-codebook softmax xent, chunked over sequence."""
        cfg = self.cfg
        Bsz, S, D = x.shape
        K = cfg.num_codebooks
        nc = cfg.loss_chunks
        cs = S // nc
        xc = x.reshape(Bsz, nc, cs, D).transpose(1, 0, 2, 3)
        lc = labels.transpose(0, 2, 1).reshape(Bsz, nc, cs, K).transpose(1, 0, 2, 3)

        w = params["head"]["w_out"]  # (K, d, V)

        @functools.partial(jax.checkpoint,
                           policy=jax.checkpoint_policies.nothing_saveable)
        def body(tot, inp):
            xb, lb = inp
            logits = jnp.einsum("bsd,kdv->bskv", xb.astype(jnp.float32),
                                w.astype(jnp.float32))
            lse = jax.nn.logsumexp(logits, axis=-1)
            lab = jnp.take_along_axis(logits, lb[..., None], axis=-1)[..., 0]
            return tot + (lse - lab).sum(), None

        tot, _ = jax.lax.scan(body, jnp.float32(0.0), (xc, lc))
        return tot / (Bsz * S * K)

    # --------------------------------------------------------------- prefill

    def prefill(self, params, batch, cache_len: int):
        """Run the full prompt, return (last-position logits, decode cache)."""
        cfg = self.cfg
        params = cast_floats(params, self.compute_dtype)
        x = self._embed_batch(params, batch)
        x = x.astype(self.compute_dtype)
        fam = cfg.family

        if fam in ("dense", "moe", "vlm", "audio"):
            apply_pref = functools.partial(self._prefill_block,
                                           cache_len=cache_len)
            x, caches = _scan_or_unroll(_maybe_remat(apply_pref, cfg), x,
                                        params["layers"], cfg)
            cache = caches
        elif fam == "ssm":
            def cycle(h, cyc_params):
                ml, sl = cyc_params

                def inner(h2, mp):
                    h2, st = B.mlstm_block_apply(mp, h2, cfg)
                    return h2, st

                h, m_states = _scan_or_unroll(inner, h, ml, cfg)
                h, s_state = B.slstm_block_apply(sl, h, cfg)
                return h, (m_states, s_state)

            x, cache = _scan_or_unroll(_maybe_remat(cycle, cfg), x,
                                       (params["mlstm"], params["slstm"]),
                                       cfg)
        elif fam == "hybrid":
            shared = params["shared_attn"]
            W = cfg.sliding_window

            def cycle(h, cyc_params):
                def inner(h2, mp):
                    h2, st = B.mamba_block_apply(mp, h2, cfg)
                    return h2, st

                h, m_states = _scan_or_unroll(inner, h, cyc_params, cfg)
                hn = L.rmsnorm(shared["norm1"], h, cfg.norm_eps)
                a, kv = attn.attention_prefill_windowed(
                    shared["attn"], hn, window=W, num_heads=cfg.num_heads,
                    num_kv_heads=cfg.num_kv_heads, head_dim=cfg.hd,
                    rope_theta=cfg.rope_theta, impl=cfg.attn_impl,
                    q_chunk=cfg.attn_q_chunk, kv_chunk=cfg.attn_kv_chunk,
                    unroll=not cfg.scan_layers)
                h = h + a
                h = h + L.mlp_apply(shared["mlp"],
                                    L.rmsnorm(shared["norm2"], h, cfg.norm_eps))
                return h, (m_states, kv)

            x, (m_cache, kv_cache) = _scan_or_unroll(
                _maybe_remat(cycle, cfg), x, params["mamba"], cfg)
            tail_cache = None
            if "mamba_tail" in params:
                def tail(h, mp):
                    h, st = B.mamba_block_apply(mp, h, cfg)
                    return h, st
                x, tail_cache = _scan_or_unroll(_maybe_remat(tail, cfg), x,
                                                params["mamba_tail"], cfg)
            cache = (m_cache, kv_cache, tail_cache)
        else:
            raise ValueError(fam)

        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = self._head_logits(params, x[:, -1:, :])
        return logits, cache

    def _prefill_block(self, h, layer_params, cache_len):
        cfg = self.cfg
        hn = L.rmsnorm(layer_params["norm1"], h, cfg.norm_eps)
        a, kv = attn.attention_prefill(layer_params["attn"], hn,
                                       cache_len, num_heads=cfg.num_heads,
                                       num_kv_heads=cfg.num_kv_heads,
                                       head_dim=cfg.hd,
                                       rope_theta=cfg.rope_theta,
                                       impl=cfg.attn_impl,
                                       q_chunk=cfg.attn_q_chunk,
                                       kv_chunk=cfg.attn_kv_chunk,
                                       unroll=not cfg.scan_layers)
        h = h + a
        if cfg.family == "moe":
            m, _ = moe_mod.moe_apply(
                layer_params["moe"],
                L.rmsnorm(layer_params["norm2"], h, cfg.norm_eps),
                top_k=cfg.top_k, capacity_factor=cfg.capacity_factor,
                router=cfg.router, sinkhorn_iters=cfg.sinkhorn_iters,
                sinkhorn_fi=cfg.sinkhorn_fi)
            h = h + m
        else:
            h = h + L.mlp_apply(layer_params["mlp"],
                                L.rmsnorm(layer_params["norm2"], h,
                                          cfg.norm_eps))
        return h, kv

    # ----------------------------------------------------------- decode path

    def init_cache(self, batch_size: int, cache_len: int):
        """Zero decode cache (shape donor for the dry-run)."""
        cfg = self.cfg
        dt = self.compute_dtype
        fam = cfg.family
        if fam in ("dense", "moe", "vlm", "audio"):
            kv = {"k": jnp.zeros((cfg.num_layers, batch_size, cache_len,
                                  cfg.num_kv_heads, cfg.hd), dt),
                  "v": jnp.zeros((cfg.num_layers, batch_size, cache_len,
                                  cfg.num_kv_heads, cfg.hd), dt)}
            return kv
        if fam == "ssm":
            cyc = cfg.num_layers // cfg.slstm_every
            m = cfg.slstm_every - 1
            H, hd = cfg.num_heads, cfg.hd
            mstate = (jnp.zeros((cyc, m, batch_size, H, hd, hd), jnp.float32),
                      jnp.zeros((cyc, m, batch_size, H, hd), jnp.float32))
            z = jnp.zeros((cyc, batch_size, H, hd), jnp.float32)
            return (mstate, (z, z, z))
        if fam == "hybrid":
            cyc = cfg.num_layers // cfg.attn_every
            tail = cfg.num_layers - cyc * cfg.attn_every
            H, hd, ds = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
            conv_dim = H * hd + 2 * ds
            W = min(cfg.sliding_window, cache_len)

            def mstates(n1, n2=None):
                shp = (n1,) if n2 is None else (n1, n2)
                return (jnp.zeros(shp + (batch_size, H, ds, hd), jnp.float32),
                        jnp.zeros(shp + (batch_size, mb.CONV_W - 1, conv_dim),
                                  jnp.float32))

            kv = {"k": jnp.zeros((cyc, batch_size, W, cfg.num_kv_heads,
                                  cfg.hd), dt),
                  "v": jnp.zeros((cyc, batch_size, W, cfg.num_kv_heads,
                                  cfg.hd), dt)}
            tail_state = mstates(tail) if tail else None
            return (mstates(cyc, cfg.attn_every), kv, tail_state)
        raise ValueError(fam)

    def decode_step(self, params, cache, tokens, index):
        """One token for every sequence. tokens: (B,1) (audio: (B,K,1)).

        index: int32 scalar — tokens already in cache. Returns
        (logits (B,1,V) [audio: (B,K,1,V)], new cache).
        """
        cfg = self.cfg
        fam = cfg.family
        params = cast_floats(params, self.compute_dtype)
        if fam == "audio":
            x = L.embed(params["embed"], tokens).sum(axis=1)
        else:
            x = L.embed(params["embed"], tokens)
        x = x.astype(self.compute_dtype)

        if fam in ("dense", "moe", "vlm", "audio"):
            decode = (B.moe_block_decode if fam == "moe"
                      else B.dense_block_decode)

            def body(h, inp):
                lp, kv = inp
                h, kv = decode(lp, h, kv, index, cfg)
                return h, kv

            x, cache = _scan_or_unroll(body, x, (params["layers"], cache),
                                       cfg)
        elif fam == "ssm":
            (m_states, s_states) = cache

            def cycle(h, inp):
                (ml, sl), (mstate, sstate) = inp

                def inner(h2, inp2):
                    mp, st = inp2
                    h2, st = B.mlstm_block_decode(mp, h2, st, cfg)
                    return h2, st

                h, mstate = _scan_or_unroll(inner, h, (ml, mstate), cfg)
                h, sstate = B.slstm_block_decode(sl, h, sstate, cfg)
                return h, (mstate, sstate)

            x, cache = _scan_or_unroll(
                cycle, x, ((params["mlstm"], params["slstm"]),
                           (tuple(m_states), tuple(s_states))), cfg)
        elif fam == "hybrid":
            m_cache, kv_cache, tail_cache = cache
            shared = params["shared_attn"]
            W = kv_cache["k"].shape[2]

            def cycle(h, inp):
                cyc_params, (mstate, kv) = inp

                def inner(h2, inp2):
                    mp, st = inp2
                    h2, st = B.mamba_block_decode(mp, h2, st, cfg)
                    return h2, st

                h, mstate = _scan_or_unroll(inner, h, (cyc_params, mstate),
                                            cfg)
                hn = L.rmsnorm(shared["norm1"], h, cfg.norm_eps)
                a, kv = attn.attention_decode_windowed(
                    shared["attn"], hn, kv, index, window=W,
                    num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
                    head_dim=cfg.hd, rope_theta=cfg.rope_theta)
                h = h + a
                h = h + L.mlp_apply(shared["mlp"],
                                    L.rmsnorm(shared["norm2"], h,
                                              cfg.norm_eps))
                return h, (mstate, kv)

            x, (m_cache, kv_cache) = _scan_or_unroll(
                cycle, x, (params["mamba"], (tuple(m_cache), kv_cache)), cfg)
            if tail_cache is not None:
                def tail(h, inp):
                    mp, st = inp
                    h, st = B.mamba_block_decode(mp, h, st, cfg)
                    return h, st
                x, tail_cache = _scan_or_unroll(
                    tail, x, (params["mamba_tail"], tuple(tail_cache)), cfg)
            cache = (m_cache, kv_cache, tail_cache)
        else:
            raise ValueError(fam)

        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = self._head_logits(params, x)
        return logits, cache

    def _head_logits(self, params, x):
        cfg = self.cfg
        if cfg.family == "audio":
            w = params["head"]["w_out"]  # (K, d, V)
            logits = jnp.einsum("bsd,kdv->bksv", x.astype(jnp.float32),
                                w.astype(jnp.float32))
            return logits
        return L.output_logits(params["head"], x.astype(jnp.float32),
                               cfg.vocab_size)


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
