"""Shared neural-net layers (pure functions over param pytrees)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def normal_init(key, shape, scale=0.02, dtype=jnp.float32):
    return (scale * jax.random.normal(key, shape)).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def rmsnorm_init(d):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(params, x, eps=1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["scale"]).astype(dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10000.0):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., T, H, hd); positions: broadcastable to (..., T)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., T, hd/2)
    cos = jnp.cos(angles)[..., None, :]                 # (..., T, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def mlp_init(key, d_model, d_ff, dtype=jnp.float32, gated=True):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "w_up": normal_init(k2, (d_model, d_ff), dtype=dtype),
        "w_down": normal_init(k3, (d_ff, d_model), dtype=dtype),
    }
    if gated:
        p["w_gate"] = normal_init(k1, (d_model, d_ff), dtype=dtype)
    return p


def mlp_apply(params, x):
    up = x @ params["w_up"]
    if "w_gate" in params:            # SwiGLU
        h = jax.nn.silu(x @ params["w_gate"]) * up
    else:                             # ungated GELU MLP (GPT-BigCode style)
        h = jax.nn.gelu(up)
    return h @ params["w_down"]


# ---------------------------------------------------------------------------
# Embedding + output head (padded vocab; see configs.base)
# ---------------------------------------------------------------------------

def embedding_init(key, padded_vocab, d_model, dtype=jnp.float32):
    return {"table": normal_init(key, (padded_vocab, d_model), dtype=dtype)}


def embed(params, token_ids):
    return jnp.take(params["table"], token_ids, axis=0)


def output_head_init(key, d_model, padded_vocab, dtype=jnp.float32):
    return {"w_out": normal_init(key, (d_model, padded_vocab), dtype=dtype)}


def output_logits(params, x, real_vocab: int):
    """Logits over the padded vocab with padding positions masked to -1e9."""
    logits = x @ params["w_out"]
    pv = logits.shape[-1]
    if pv > real_vocab:
        mask = jnp.where(jnp.arange(pv) < real_vocab, 0.0, -1e9)
        logits = logits + mask.astype(logits.dtype)
    return logits


def chunked_softmax_xent(params, x, labels, real_vocab: int,
                         num_chunks: int = 8, label_mask=None,
                         matmul_f32: bool = True):
    """Cross-entropy without materializing full (B, S, V) logits.

    Scans over sequence chunks; each chunk computes its logits, its
    logsumexp, and the label logit, then discards the logits. Memory is
    O(B * S/num_chunks * V) instead of O(B * S * V).
    """
    B, S, D = x.shape
    assert S % num_chunks == 0, (S, num_chunks)
    cs = S // num_chunks
    xc = x.reshape(B, num_chunks, cs, D).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, num_chunks, cs).transpose(1, 0, 2)
    if label_mask is None:
        mc = jnp.ones((num_chunks, B, cs), jnp.float32)
    else:
        mc = label_mask.reshape(B, num_chunks, cs).transpose(1, 0, 2)

    # remat: without it the scan's backward saves every chunk's logits —
    # exactly the (B, S, V) buffer this chunking exists to avoid.
    @functools.partial(jax.checkpoint,
                       policy=jax.checkpoint_policies.nothing_saveable)
    def body(carry, inp):
        tot, cnt = carry
        xb, lb, mb = inp
        xb = xb.astype(jnp.float32) if matmul_f32 else xb
        logits = output_logits(params, xb, real_vocab).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        lab = jnp.take_along_axis(logits, lb[..., None], axis=-1)[..., 0]
        nll = (lse - lab) * mb
        return (tot + nll.sum(), cnt + mb.sum()), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)),
                                 (xc, lc, mc))
    return tot / jnp.maximum(cnt, 1.0)
