"""GQA attention: train / prefill / decode (KV cache), sliding window."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, normal_init
from repro.parallel.sharding import constrain

NEG_INF = -1e9


def attention_init(key, d_model, num_heads, num_kv_heads, head_dim,
                   dtype=jnp.float32):
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "w_q": normal_init(kq, (d_model, num_heads * head_dim), dtype=dtype),
        "w_k": normal_init(kk, (d_model, num_kv_heads * head_dim), dtype=dtype),
        "w_v": normal_init(kv, (d_model, num_kv_heads * head_dim), dtype=dtype),
        "w_o": normal_init(ko, (num_heads * head_dim, d_model), dtype=dtype),
    }


def _split_heads(x, n, hd):
    B, T, _ = x.shape
    return x.reshape(B, T, n, hd)


def _repeat_kv(k, groups):
    # (B, S, kvH, hd) -> (B, S, H, hd) by repeating each kv head
    return jnp.repeat(k, groups, axis=2)


def _causal_mask(Tq, Tk, q_offset, window: int = 0):
    """(Tq, Tk) additive mask. q position = q_offset + i; window 0 = full."""
    qpos = q_offset + jnp.arange(Tq)[:, None]
    kpos = jnp.arange(Tk)[None, :]
    ok = kpos <= qpos
    if window:
        ok = jnp.logical_and(ok, kpos > qpos - window)
    return jnp.where(ok, 0.0, NEG_INF)


def attention_apply(params, x, *, num_heads, num_kv_heads, head_dim,
                    rope_theta=10000.0, window: int = 0, positions=None,
                    impl: str = "naive", q_chunk: int = 512,
                    kv_chunk: int = 1024, unroll: bool = False):
    """Full-sequence causal attention (training / prefill compute).

    impl="naive": einsum path, materializes (B, H, T, T) scores — the
    straightforward baseline (and what XLA does without a fused kernel).
    impl="flash": chunked online-softmax (FlashAttention schedule in pure
    jnp) — temporaries are (B, H, q_chunk, kv_chunk); the memory roofline
    term drops by ~T/kv_chunk. ``unroll`` unrolls the chunk loops with
    causal culling (used by the dry-run for faithful cost_analysis).
    """
    B, T, D = x.shape
    q = _split_heads(x @ params["w_q"], num_heads, head_dim)
    k = _split_heads(x @ params["w_k"], num_kv_heads, head_dim)
    v = _split_heads(x @ params["w_v"], num_kv_heads, head_dim)
    if positions is None:
        positions = jnp.arange(T)[None, :]
    q = apply_rope(q, positions, rope_theta)
    k = apply_rope(k, positions, rope_theta)

    # GQA via grouped einsums — KV heads are NEVER repeated/materialized
    # (for MQA archs like granite-34b the repeat would be a 48x KV blowup).
    groups = num_heads // num_kv_heads

    if impl == "flash":
        out = _flash_attention(q, k, v, head_dim=head_dim, window=window,
                               q_chunk=min(q_chunk, T),
                               kv_chunk=min(kv_chunk, T), unroll=unroll,
                               groups=groups)
    else:
        q5 = q.reshape(B, T, num_kv_heads, groups, head_dim)
        scores = jnp.einsum("bqkgd,bskd->bkgqs", q5, k).astype(jnp.float32)
        scores = scores / jnp.sqrt(head_dim).astype(jnp.float32)
        scores = scores + _causal_mask(T, T, 0, window)[None, None, None]
        probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    out = out.reshape(B, T, num_heads * head_dim)
    return out @ params["w_o"], (k, v)


def _flash_attention(q, k, v, *, head_dim, window, q_chunk, kv_chunk,
                     unroll, groups=1):
    """Chunked online-softmax causal GQA attention (KV heads not repeated).

    q: (B, T, H, hd); k,v: (B, T, kvH, hd) with H = kvH * groups."""
    B, T, H, hd = q.shape
    kvH = k.shape[2]
    assert T % q_chunk == 0 and T % kv_chunk == 0, (T, q_chunk, kv_chunk)
    nq, nk = T // q_chunk, T // kv_chunk
    scale = 1.0 / jnp.sqrt(head_dim)
    qt = q.transpose(0, 2, 1, 3)            # (B, H, T, hd)
    # context parallelism: optionally shard the q sequence dim over 'model'
    # (KV replicated) — the TP fallback when heads don't divide the axis.
    qt = constrain(qt, "attn_q")
    qt = qt.reshape(B, kvH, groups, T, hd)  # (B, kvH, g, T, hd)
    kt = k.transpose(0, 2, 1, 3)            # (B, kvH, T, hd)
    vt = v.transpose(0, 2, 1, 3)

    def kv_step(qi, q_blk, carry, ki):
        acc, m, l = carry
        k_blk = jax.lax.dynamic_slice_in_dim(kt, ki * kv_chunk, kv_chunk, 2)
        v_blk = jax.lax.dynamic_slice_in_dim(vt, ki * kv_chunk, kv_chunk, 2)
        s = jnp.einsum("bkgqd,bksd->bkgqs", q_blk, k_blk).astype(jnp.float32)
        s = s * scale
        qpos = qi * q_chunk + jnp.arange(q_chunk)[:, None]
        kpos = ki * kv_chunk + jnp.arange(kv_chunk)[None, :]
        ok = kpos <= qpos
        if window:
            ok = jnp.logical_and(ok, kpos > qpos - window)
        s = jnp.where(ok[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bkgqs,bksd->bkgqd", p.astype(v_blk.dtype), v_blk).astype(jnp.float32)
        return acc, m_new, l

    def q_block(qi):
        q_blk = jax.lax.dynamic_slice_in_dim(qt, qi * q_chunk, q_chunk, 3)
        acc0 = jnp.zeros((B, kvH, groups, q_chunk, hd), jnp.float32)
        m0 = jnp.full((B, kvH, groups, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, kvH, groups, q_chunk), jnp.float32)
        # causal culling: kv chunks strictly above the diagonal are skipped
        hi = ((qi + 1) * q_chunk + kv_chunk - 1) // kv_chunk
        if unroll:
            carry = (acc0, m0, l0)
            for ki in range(min(hi, nk)):
                carry = kv_step(qi, q_blk, carry, ki)
            acc, m, l = carry
        else:
            def body(carry, ki):
                return kv_step(qi, q_blk, carry, ki), None
            (acc, m, l), _ = jax.lax.scan(
                body, (acc0, m0, l0), jnp.arange(min(hi, nk)))
        return (acc / jnp.maximum(l, 1e-30)[..., None])

    out = jnp.concatenate([q_block(qi) for qi in range(nq)], axis=3)
    out = out.reshape(B, H, T, hd)
    out = constrain(out, "attn_q")
    return out.transpose(0, 2, 1, 3).astype(q.dtype)  # (B, T, H, hd)


def attention_prefill(params, x, cache_len, **kw):
    """Prefill: run full attention and emit a right-padded KV cache."""
    num_kv_heads = kw["num_kv_heads"]
    head_dim = kw["head_dim"]
    B, T, _ = x.shape
    k = _split_heads(x @ params["w_k"], num_kv_heads, head_dim)
    v = _split_heads(x @ params["w_v"], num_kv_heads, head_dim)
    positions = jnp.arange(T)[None, :]
    k = apply_rope(k, positions, kw.get("rope_theta", 10000.0))
    out, _ = attention_apply(params, x, **kw)
    pad = cache_len - T
    kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return out, {"k": kc, "v": vc}


def attention_prefill_windowed(params, x, *, window, num_heads, num_kv_heads,
                               head_dim, rope_theta=10000.0, impl="naive",
                               q_chunk=512, kv_chunk=1024, unroll=False):
    """Sliding-window prefill emitting a RING-BUFFER KV cache of size window.

    Absolute position p is stored at slot p % window; only the last
    min(T, window) positions survive (older ones are out of the window by
    construction). Keys are stored post-RoPE (absolute positions).
    """
    B, T, _ = x.shape
    out, _ = attention_apply(params, x, num_heads=num_heads,
                             num_kv_heads=num_kv_heads, head_dim=head_dim,
                             rope_theta=rope_theta, window=window, impl=impl,
                             q_chunk=q_chunk, kv_chunk=kv_chunk,
                             unroll=unroll)
    k = _split_heads(x @ params["w_k"], num_kv_heads, head_dim)
    v = _split_heads(x @ params["w_v"], num_kv_heads, head_dim)
    k = apply_rope(k, jnp.arange(T)[None, :], rope_theta)

    W = window
    keep = min(T, W)
    k_tail, v_tail = k[:, T - keep:], v[:, T - keep:]
    slots = (jnp.arange(T - keep, T) % W)
    kc = jnp.zeros((B, W, num_kv_heads, head_dim), k.dtype).at[:, slots].set(k_tail)
    vc = jnp.zeros((B, W, num_kv_heads, head_dim), v.dtype).at[:, slots].set(v_tail)
    return out, {"k": kc, "v": vc}


def _scatter_cache_update(cache_t, new, slot):
    """Write ``new`` (B, 1, kvH, hd) at sequence position ``slot``.

    Implemented as a one-hot select instead of dynamic_update_slice: a
    runtime-indexed DUS on a sequence-SHARDED dim is unpartitionable (XLA
    SPMD falls back to gathering the whole cache on every step — measured
    43 GB/token of all-gather on granite-3-2b decode); the select is
    elementwise over the sharded dim and keeps the cache fully local.
    """
    S = cache_t.shape[1]
    hit = (jnp.arange(S, dtype=jnp.int32) == slot)[None, :, None, None]
    return jnp.where(hit, new.astype(cache_t.dtype), cache_t)


def attention_decode_windowed(params, x, cache, cache_index, *, window,
                              num_heads, num_kv_heads, head_dim,
                              rope_theta=10000.0):
    """Single-token decode against a ring-buffer cache of size window.

    Slot s holds absolute position p = cache_index - ((cache_index - s) mod
    window) after this token is written; entries with p < 0 are masked.
    """
    B, T, D = x.shape
    assert T == 1
    W = cache["k"].shape[1]
    q = _split_heads(x @ params["w_q"], num_heads, head_dim)
    k_new = _split_heads(x @ params["w_k"], num_kv_heads, head_dim)
    v_new = _split_heads(x @ params["w_v"], num_kv_heads, head_dim)
    pos = jnp.full((B, 1), cache_index, jnp.int32)
    q = apply_rope(q, pos, rope_theta)
    k_new = apply_rope(k_new, pos, rope_theta)

    slot = jnp.mod(cache_index, W)
    k = _scatter_cache_update(cache["k"], k_new, slot)
    v = _scatter_cache_update(cache["v"], v_new, slot)

    s = jnp.arange(W)[None, None, None, :]
    p = cache_index - jnp.mod(cache_index - s, W)
    out = _grouped_decode_attention(q, k, v, p >= 0, num_heads, num_kv_heads,
                                    head_dim)
    out = out @ params["w_o"]
    return out, {"k": k, "v": v}


def _grouped_decode_attention(q, k, v, valid, num_heads, num_kv_heads,
                              head_dim):
    """GQA decode attention WITHOUT repeating KV heads.

    Repeating kvH -> H forces XLA to reshard a sequence-sharded cache onto
    heads (a full-cache regather per layer per token). Keeping the kvH dim
    in the einsum lets the softmax/contraction run on the sequence-sharded
    cache (distributed flash-decoding; XLA inserts only the small psum).

    q: (B, 1, H, hd); k,v: (B, S, kvH, hd); valid: bool (1,1,1,S)-broadcast.
    Returns (B, 1, H*hd).
    """
    B = q.shape[0]
    groups = num_heads // num_kv_heads
    q5 = q.reshape(B, 1, num_kv_heads, groups, head_dim)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", q5, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(head_dim).astype(jnp.float32)
    scores = jnp.where(valid, scores, NEG_INF)          # (B,kvH,g,1,S)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(B, 1, num_heads * head_dim)


def attention_decode(params, x, cache, cache_index, *, num_heads,
                     num_kv_heads, head_dim, rope_theta=10000.0,
                     window: int = 0):
    """Single-token decode against a KV cache.

    x: (B, 1, D); cache: {"k","v"} of (B, S, kvH, hd); cache_index: scalar
    int32 — number of valid tokens already in the cache.
    Returns (out (B, 1, D), updated cache).
    """
    B, T, D = x.shape
    assert T == 1
    S = cache["k"].shape[1]
    q = _split_heads(x @ params["w_q"], num_heads, head_dim)
    k_new = _split_heads(x @ params["w_k"], num_kv_heads, head_dim)
    v_new = _split_heads(x @ params["w_v"], num_kv_heads, head_dim)
    pos = jnp.full((B, 1), cache_index, jnp.int32)
    q = apply_rope(q, pos, rope_theta)
    k_new = apply_rope(k_new, pos, rope_theta)

    k = _scatter_cache_update(cache["k"], k_new, cache_index)
    v = _scatter_cache_update(cache["v"], v_new, cache_index)

    kpos = jnp.arange(S)[None, None, None, :]
    ok = kpos <= cache_index
    if window:
        ok = jnp.logical_and(ok, kpos > cache_index - window)
    out = _grouped_decode_attention(q, k, v, ok, num_heads, num_kv_heads,
                                    head_dim)
    out = out @ params["w_o"]
    return out, {"k": k, "v": v}
