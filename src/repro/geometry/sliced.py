"""Sliced UOT: average exact 1-D solves over random lines — no M*N.

The serving degrade ladder's deepest tier (``repro.serve``'s overload
model, level 2). A point-cloud UOT problem is projected onto ``n_proj``
random unit directions; each projection is an exact 1-D KL-UOT solve
(``core.solve_1d`` — O((M+N) log(M+N)), certified gap, no epsilon), run
as ONE vmapped launch over the stacked projections. Total work is
O(n_proj * (M+N) log(M+N)) with O(n_proj * (M+N)) memory — no M*N
bytes, no M*N FLOPs, which is exactly what an overloaded scheduler
wants to promise.

Cost calibration: with uniform unit directions ``theta``,
``E_theta[d * (theta . delta)^2] = ||delta||^2``, so every slice uses
``cost_scale = d / scale`` and the sliced estimate is comparable to
``PointCloudGeometry``'s ``C = ||x - y||^2 / scale`` (same ``scale``
semantics as ``from_points``).

Estimate semantics (what ``est_error`` means downstream): for each
slice, the *projection of the true optimal plan* is feasible for that
slice's 1-D problem and has identical KL terms, so each slice's optimum
lower-bounds the true UOT cost in expectation — ``mean(dual)`` is a
certified-per-slice statistical lower bound, and the reported
``est_error`` combines the mean certified FW gap (solver error) with
the Monte-Carlo standard error over directions (slicing error). It is
an uncertainty label for the *value*; the lifted coupling is an
averaged monotone-plan heuristic, not an optimal plan.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.solve_1d import solve_1d

__all__ = ["SlicedUOTResult", "sliced_directions", "sliced_uot",
           "lift_coupling_np"]


@dataclasses.dataclass(frozen=True)
class SlicedUOTResult:
    """Sliced-UOT estimate with an honest error label."""

    cost: float          # mean per-slice primal — the sliced estimate
    lower_bound: float   # mean per-slice dual — statistical lower bound
    std_err: float       # Monte-Carlo std error of the mean over slices
    mean_gap: float      # mean certified per-slice FW gap
    est_error: float     # mean_gap + 2 * std_err — the ladder's label
    n_proj: int
    primal: np.ndarray   # (n_proj,) per-slice primal values
    dual: np.ndarray     # (n_proj,) per-slice dual values
    seg_i: np.ndarray    # (n_proj, M+N) per-slice plan segments
    seg_j: np.ndarray
    seg_w: np.ndarray


def sliced_directions(d: int, n_proj: int, seed: int = 0) -> jax.Array:
    """``n_proj`` uniform random unit directions in R^d, seeded."""
    key = jax.random.PRNGKey(seed)
    theta = jax.random.normal(key, (n_proj, d), jnp.float32)
    return theta / jnp.linalg.norm(theta, axis=1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("n_fw",))
def _sliced_solve(px, py, a, b, rho, cost_scale, *, n_fw):
    def one(pxi, pyi):
        return solve_1d(pxi, a, pyi, b, rho,
                        cost_scale=cost_scale, n_fw=n_fw)

    return jax.vmap(one)(px, py)


def sliced_uot(x, y, a, b, *, rho: float, scale: float = 1.0,
               n_proj: int = 32, seed: int = 0,
               n_fw: int = 16) -> SlicedUOTResult:
    """Sliced KL-UOT estimate between point clouds.

    ``x``: (M, d), ``y``: (N, d), ``a``: (M,), ``b``: (N,). ``rho`` is
    the marginal KL weight (``cfg.reg_m``), ``scale`` matches
    ``PointCloudGeometry.from_points``. One compiled vmapped launch over
    ``n_proj`` projections; recompiles only on new (shape, n_fw).
    """
    x = jnp.asarray(x, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    d = x.shape[-1]
    theta = sliced_directions(d, n_proj, seed)
    px = jnp.dot(x, theta.T).T          # (n_proj, M)
    py = jnp.dot(y, theta.T).T          # (n_proj, N)
    out = _sliced_solve(px, py, jnp.asarray(a, jnp.float32),
                        jnp.asarray(b, jnp.float32),
                        jnp.asarray(rho, jnp.float32),
                        jnp.asarray(d / scale, jnp.float32), n_fw=n_fw)
    primal = np.asarray(out["primal"], np.float64)
    dual = np.asarray(out["dual"], np.float64)
    cost = float(primal.mean())
    std_err = float(primal.std(ddof=1) / np.sqrt(n_proj)) if n_proj > 1 else 0.0
    mean_gap = float(np.maximum(primal - dual, 0.0).mean())
    return SlicedUOTResult(
        cost=cost,
        lower_bound=float(dual.mean()),
        std_err=std_err,
        mean_gap=mean_gap,
        est_error=mean_gap + 2.0 * std_err,
        n_proj=n_proj,
        primal=primal,
        dual=dual,
        seg_i=np.asarray(out["seg_i"]),
        seg_j=np.asarray(out["seg_j"]),
        seg_w=np.asarray(out["seg_w"]),
    )


def lift_coupling_np(res: SlicedUOTResult, M: int, N: int) -> np.ndarray:
    """Average the per-slice monotone plans into a dense (M, N) coupling.

    A result-shaped payload for clients that expect a coupling from the
    degraded tier — the dense buffer is only materialized here, on the
    host, for delivery; the solve itself never touched M*N anything.
    Marginals are the average of the per-slice reweighted marginals.
    """
    P = np.zeros((M, N), np.float64)
    w = res.seg_w / res.n_proj
    np.add.at(P, (res.seg_i.ravel(), res.seg_j.ravel()), w.ravel())
    return P
