"""Point-cloud squared-Euclidean geometry: cost tiles from coordinates.

For point-cloud workloads the cost ``C_ij = ||x_i - y_j||^2`` is a function
of ``O((M + N) * d)`` coordinate data, so a dense ``C`` in HBM is pure
wasted bandwidth (Lakshmanan & Pichler, arXiv:2306.13618, make the same
observation for fast UOT kernel evaluation). This module holds both

- the ``PointCloudGeometry`` pytree (coordinates + squared norms + an
  optional per-problem valid-count mask for zero-padded batches), and
- the **shared tile arithmetic** (``pairwise_dot`` / ``cost_tile`` /
  ``gibbs_tile``) that every consumer — the materializing jnp mirrors
  here, the streamed Pallas kernels in ``kernels.uot_geometry``, and the
  resident kernel in ``kernels.uot_resident`` — evaluates.

Bitwise-reproducibility rules (tests/test_geometry.py asserts the result):

1. **Squared norms are precomputed once**, at geometry construction, by a
   standalone jitted helper, and carried as concrete arrays. Recomputing
   ``sum_k x_k^2`` inside each consumer would put the same ``mul+add``
   chain into different XLA fusion contexts, where FMA contraction fires
   differently and the low bits diverge.
2. **The pairwise dot is an unrolled elementwise sum over d** (d is small:
   2-8 for the targeted workloads), not a gemm. A gemm's accumulation
   order depends on how the backend tiles it, so a full-matrix matmul and
   a row-block tile matmul round differently; an unrolled elementwise
   chain is blocking-invariant.
3. ``reg`` and ``scale`` enter as **static Python floats** baked into the
   jaxpr, so the division lowers identically everywhere.

Under those rules the materialized mirror ``kernel(reg)`` and the on-chip
tile evaluation produce bit-identical fp32 values, which is what lets the
ops dispatcher route between the dense-load and tile-compute paths without
changing couplings.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.scipy.special import logsumexp

from repro.geometry.base import Geometry


def sq_norms(p: jax.Array) -> jax.Array:
    """``||p_k||^2`` over the last axis, unrolled: (..., K, d) -> (..., K)."""
    n = p[..., 0] * p[..., 0]
    for k in range(1, p.shape[-1]):
        n = n + p[..., k] * p[..., k]
    return n


_sq_norms_jit = jax.jit(sq_norms)


def pairwise_dot(x: jax.Array, y: jax.Array) -> jax.Array:
    """``x @ y^T`` over the last axis as an unrolled elementwise sum.

    x: (..., m, d); y: (..., n, d) -> (..., m, n). Rule 2 above: the
    unrolled chain rounds identically whether evaluated on the full
    matrix or on a row-block tile, which a gemm does not guarantee.
    """
    d = x.shape[-1]
    out = x[..., :, 0:1] * y[..., :, 0][..., None, :]
    for k in range(1, d):
        out = out + x[..., :, k:k + 1] * y[..., :, k][..., None, :]
    return out


def cost_tile(x, xn, y, yn, *, scale: float = 1.0) -> jax.Array:
    """``(||x_i - y_j||^2) / scale`` for a coordinate tile.

    x: (..., m, d); xn: (..., m, 1); y: (..., n, d); yn: (..., 1, n).
    The norms are taken as inputs (rule 1), the dot is unrolled (rule 2),
    ``scale`` is a static float (rule 3).
    """
    sq = xn + yn - 2.0 * pairwise_dot(x, y)
    if scale != 1.0:
        sq = sq / scale
    return sq


def gibbs_tile(x, xn, y, yn, *, reg: float, scale: float = 1.0) -> jax.Array:
    """``exp(-cost_tile / reg)`` — the Gibbs-kernel tile, computed with the
    exact arithmetic of the two-step dense path (materialize ``C``, then
    exponentiate).

    The ``optimization_barrier`` between the two steps is load-bearing for
    bitwise parity (rule 4, as it were): without it XLA *rematerializes*
    the cost chain inside the exp fusion, where FMA contraction can round
    an ulp differently than the standalone cost computation — so
    ``exp(-stored_C / reg)`` and the fused evaluation would disagree in
    the low bit. The barrier pins the exp's input to exactly the value
    the dense path stores. (Rounding, not performance: the barrier cuts
    one fusion edge on an elementwise chain.)
    """
    sq = jax.lax.optimization_barrier(cost_tile(x, xn, y, yn, scale=scale))
    return jnp.exp(-sq / reg)


def valid_mask(m: int, n: int, m_valid, n_valid) -> jax.Array:
    """(..., m, n) bool mask of in-bounds entries for zero-padded problems.

    ``m_valid`` / ``n_valid`` are int scalars or (...,) arrays (one count
    per batched problem). Entries at or beyond the valid counts must be
    *exactly zero* in any materialized kernel/coupling — that is what
    makes zero-padding a no-op for the rescaling math, same as padding a
    dense matrix with zero rows/cols.
    """
    rows = jnp.arange(m)
    cols = jnp.arange(n)
    mv = jnp.asarray(m_valid)[..., None, None]
    nv = jnp.asarray(n_valid)[..., None, None]
    return (rows[:, None] < mv) & (cols[None, :] < nv)


_MIRROR_LANE = 128  # evaluate mirrors at the kernel path's lane alignment


@functools.partial(jax.jit, static_argnames=("reg", "scale"))
def _kernel_mirror(x, xn, y, yn, *, reg: float, scale: float) -> jax.Array:
    return gibbs_tile(x, xn[..., :, None], y, yn[..., None, :],
                      reg=reg, scale=scale)


@functools.partial(jax.jit, static_argnames=("scale",))
def _cost_mirror(x, xn, y, yn, *, scale: float) -> jax.Array:
    return cost_tile(x, xn[..., :, None], y, yn[..., None, :], scale=scale)


@dataclasses.dataclass(frozen=True)
class PointCloudGeometry(Geometry):
    """Squared-Euclidean geometry of two coordinate clouds.

    Fields (single problem; a leading batch dim on every array field gives
    a batched geometry, as assembled by the serving layer):
      x, y:   (M, d) / (N, d) fp32 coordinates.
      xn, yn: (M,) / (N,) precomputed squared norms (rule 1 — use
              ``from_points`` unless you already hold them).
      m_valid, n_valid: optional per-problem valid counts (int32 scalars /
              (B,) arrays) for zero-padded stacks; rows/cols beyond them
              evaluate to exactly 0 in every kernel tile. A kernel-path
              construct: ``kernel()`` and the Pallas tile kernels honor
              them, while ``cost()`` and the lazy applications refuse
              masked geometries (slice the clouds instead — only the
              Gibbs kernel has a natural masked value).
      scale:  static cost divisor (``C = ||x - y||^2 / scale``), e.g. a
              known cost bound for normalized-cost applications.

    ``is_implicit=True``: the kernel stack computes this geometry's Gibbs
    tiles in VMEM from the coordinates; no ``M*N`` cost array exists in
    HBM on that path, and a serving request ships ``(M + N) * d`` floats
    instead of ``M * N``.
    """

    x: jax.Array
    y: jax.Array
    xn: jax.Array
    yn: jax.Array
    m_valid: jax.Array | None = None
    n_valid: jax.Array | None = None
    scale: float = 1.0

    @classmethod
    def from_points(cls, x, y, *, scale: float = 1.0,
                    m_valid=None, n_valid=None) -> "PointCloudGeometry":
        """Canonical constructor: precomputes the squared norms once.

        Call outside jit so the norms are concrete (rule 1 in the module
        docstring); inside a trace the stability guarantee is down to the
        caller keeping every consumer in the same trace.
        """
        x = jnp.asarray(x, jnp.float32)
        y = jnp.asarray(y, jnp.float32)
        if x.shape[-1] != y.shape[-1]:
            raise ValueError(f"coordinate dims differ: {x.shape} vs {y.shape}")
        return cls(x=x, y=y, xn=_sq_norms_jit(x), yn=_sq_norms_jit(y),
                   m_valid=None if m_valid is None else jnp.asarray(
                       m_valid, jnp.int32),
                   n_valid=None if n_valid is None else jnp.asarray(
                       n_valid, jnp.int32),
                   scale=float(scale))

    is_implicit = True

    @property
    def shape(self) -> tuple[int, int]:
        return (self.x.shape[-2], self.y.shape[-2])

    @property
    def batch_shape(self) -> tuple[int, ...]:
        return tuple(self.x.shape[:-2])

    @property
    def dim(self) -> int:
        return self.x.shape[-1]

    def payload_nbytes(self) -> int:
        """Bytes a serving request carrying this geometry ships —
        coordinates + precomputed squared norms, ``(M + N) * (d + 1)``
        fp32 values per problem — vs ``M * N * 4`` for the dense kernel.

        This O(M + N) payload is what makes coordinate requests cheap to
        *route*: the cluster scheduler can place (or re-place) them on any
        device shard for the cost of a vector transfer, and the M*N Gibbs
        kernel only ever materializes on the owning device at admission
        (``repro.cluster``'s routing decision table cites this number).
        """
        M, N = self.shape
        per_problem = 4 * (M + N) * (self.dim + 1)
        batch = 1
        for dim in self.batch_shape:
            batch *= int(dim)
        return batch * per_problem

    def _lane_padded_cols(self):
        """Eagerly zero-pad the column cloud to the 128-lane multiple the
        kernel path computes at; the mirrors evaluate on the padded shape
        and the caller slices the result back.

        Bitwise rule 4: SIMD and scalar-tail codegen round differently
        (libm scalar exp vs vectorized exp; FMA contraction in the vector
        body only), so an unpadded (M, N) evaluation disagrees with the
        kernel path's lane-padded tiles in the last ``N % vector-width``
        columns. The padding must happen *outside* the jitted mirror —
        a pad fused into the evaluation loop changes its codegen again.
        """
        N = self.y.shape[-2]
        pad = (-N) % _MIRROR_LANE
        if not pad:
            return self.y, self.yn, N
        y = jnp.pad(self.y, [(0, 0)] * (self.y.ndim - 2)
                    + [(0, pad), (0, 0)])
        yn = jnp.pad(self.yn, [(0, 0)] * (self.yn.ndim - 1) + [(0, pad)])
        return y, yn, N

    def cost(self) -> jax.Array:
        """Dense ``C = ||x - y||^2 / scale`` (tests / explicit-C parity).

        Undefined for valid-count-masked geometries (a masked kernel
        entry is 0, i.e. cost +inf — not a usable dense C); slice the
        clouds instead.
        """
        self._require_unmasked("cost()")
        y, yn, N = self._lane_padded_cols()
        return _cost_mirror(self.x, self.xn, y, yn,
                            scale=self.scale)[..., :N]

    def kernel(self, reg: float) -> jax.Array:
        """Materialized Gibbs mirror — bit-identical to the on-chip tiles."""
        y, yn, N = self._lane_padded_cols()
        K = _kernel_mirror(self.x, self.xn, y, yn, reg=float(reg),
                           scale=self.scale)[..., :N]
        if self.m_valid is None and self.n_valid is None:
            return K
        M = self.shape[0]
        mv = M if self.m_valid is None else self.m_valid
        nv = N if self.n_valid is None else self.n_valid
        return jnp.where(valid_mask(M, N, mv, nv), K, 0.0)

    # -- lazy applications (u/v and log-domain solvers): row-chunked so the
    # peak live cost tile is (chunk, N), not (M, N) ------------------------

    _CHUNK = 128

    def _require_unmasked(self, what: str):
        # valid-count masks are a *kernel-path* construct (they stand in
        # for the zero rows/cols of a padded dense stack, and only the
        # Gibbs kernel has a natural masked value, 0). Silently ignoring
        # them here would leak the padded coordinates' exp(0)-sized
        # entries into every reduction, so refuse loudly: for the lazy /
        # cost paths, slice the clouds instead of masking them.
        if self.m_valid is not None or self.n_valid is not None:
            raise ValueError(
                f"{what} is not defined for valid-count-masked geometries;"
                f" slice the coordinate clouds (x[:m], y[:n]) instead")

    def _row_chunks(self):
        M, d = self.x.shape[-2], self.x.shape[-1]
        if len(self.batch_shape):
            raise NotImplementedError(
                "lazy applications are per-problem; batched geometries are "
                "consumed by the batched solve entry points")
        self._require_unmasked("a lazy kernel/lse application")
        pad = (-M) % self._CHUNK
        x = jnp.pad(self.x, ((0, pad), (0, 0)))
        xn = jnp.pad(self.xn, (0, pad))
        return (x.reshape(-1, self._CHUNK, d),
                xn.reshape(-1, self._CHUNK), M)

    def apply_kernel(self, v: jax.Array, reg: float) -> jax.Array:
        reg, scale = float(reg), self.scale
        xc, xnc, M = self._row_chunks()

        def body(args):
            xb, xnb = args
            Kb = gibbs_tile(xb, xnb[:, None], self.y, self.yn[None, :],
                            reg=reg, scale=scale)
            return Kb @ v

        return jax.lax.map(body, (xc, xnc)).reshape(-1)[:M]

    def apply_kernel_T(self, u: jax.Array, reg: float) -> jax.Array:
        reg, scale = float(reg), self.scale
        xc, xnc, M = self._row_chunks()
        uc = jnp.pad(u, (0, (-M) % self._CHUNK)).reshape(-1, self._CHUNK)

        def body(args):
            xb, xnb, ub = args
            Kb = gibbs_tile(xb, xnb[:, None], self.y, self.yn[None, :],
                            reg=reg, scale=scale)
            return ub @ Kb

        return jnp.sum(jax.lax.map(body, (xc, xnc, uc)), axis=0)

    def apply_lse(self, z: jax.Array, reg: float) -> jax.Array:
        reg, scale = float(reg), self.scale
        xc, xnc, M = self._row_chunks()

        def body(args):
            xb, xnb = args
            Cb = cost_tile(xb, xnb[:, None], self.y, self.yn[None, :],
                           scale=scale)
            return logsumexp((z[None, :] - Cb) / reg, axis=1)

        return jax.lax.map(body, (xc, xnc)).reshape(-1)[:M]

    def apply_lse_T(self, z: jax.Array, reg: float) -> jax.Array:
        reg, scale = float(reg), self.scale
        xc, xnc, M = self._row_chunks()
        # padded rows must not contribute: push their terms to -inf
        zc = jnp.pad(z, (0, (-M) % self._CHUNK),
                     constant_values=-jnp.inf).reshape(-1, self._CHUNK)

        def body(args):
            xb, xnb, zb = args
            Cb = cost_tile(xb, xnb[:, None], self.y, self.yn[None, :],
                           scale=scale)
            return logsumexp((zb[:, None] - Cb) / reg, axis=0)

        return logsumexp(jax.lax.map(body, (xc, xnc, zc)), axis=0)


jax.tree_util.register_dataclass(
    PointCloudGeometry,
    data_fields=["x", "y", "xn", "yn", "m_valid", "n_valid"],
    meta_fields=["scale"])
