"""Implicit cost geometries: name the cost *source*, not its M*N bytes.

``Geometry`` abstracts where a UOT problem's ground cost comes from, so
every consumer (core solvers, Pallas kernel stack, serving) can pick the
cheapest faithful evaluation instead of demanding a dense HBM-resident
``C``:

- ``DenseGeometry(C)`` — the explicit matrix; historical semantics.
- ``PointCloudGeometry.from_points(x, y)`` — squared-Euclidean cost of
  coordinate clouds; the kernel stack computes Gibbs tiles in VMEM from
  ``O((M + N) * d)`` coordinates (never materializing ``C``), serving
  ships coordinates instead of matrices, and the resident tier's VMEM
  budget shrinks to the coupling alone.
- ``GridGeometry(factors)`` — separable per-axis costs; kernel
  applications are k small per-axis contractions and never form ``M*N``.
- ``sliced`` — sliced UOT over random 1-D projections (exact
  ``core.solve_1d`` per line, vmapped): the O(n_proj * (M+N) log(M+N))
  estimate the serving degrade ladder falls back to under overload.

See ``base.py`` for the bitwise-reproducibility contract that lets the
solver tiers dispatch on memory layout without changing results.
"""
from repro.geometry.base import Geometry
from repro.geometry.dense import DenseGeometry
from repro.geometry.grid import GridGeometry
from repro.geometry.pointcloud import PointCloudGeometry
from repro.geometry.sliced import (SlicedUOTResult, lift_coupling_np,
                                   sliced_directions, sliced_uot)

__all__ = ["Geometry", "DenseGeometry", "GridGeometry",
           "PointCloudGeometry", "SlicedUOTResult", "sliced_directions",
           "sliced_uot", "lift_coupling_np"]
