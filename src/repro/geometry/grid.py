"""Separable grid geometry: per-axis cost factors, never an M*N array.

For histograms supported on product grids (images, voxel grids, tensor
meshes) with a separable ground cost

    C[(i_1..i_k), (j_1..j_k)] = sum_l C_l[i_l, j_l]

the Gibbs kernel factorizes as a Kronecker product,
``K = kron(K_1, ..., K_k)`` with ``K_l = exp(-C_l / reg)``, and every
kernel application the u/v and log-domain solvers need is a sequence of
*small per-axis contractions*:

    K @ v      = fold_l ( K_l tensordot_l V )        — k small matmuls
    lse update = fold_l ( logsumexp_l over axis l )  — staged, stabilized

Cost per application drops from ``O(M * N)`` to
``O(sum_l m_l * n_l * prod_{r != l} n_r)`` flops with ``O(M + N)`` state —
the geometry never forms an ``M*N`` array at all (``kernel()`` /
``cost()`` exist as materializing mirrors for tests and for the
matrix-scaling tiers, which iterate on a dense coupling by construction).
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
from jax.scipy.special import logsumexp

from repro.geometry.base import Geometry


@dataclasses.dataclass(frozen=True)
class GridGeometry(Geometry):
    """Geometry of a separable cost over a k-axis product grid.

    ``factors`` are the per-axis cost matrices ``C_l`` of shape
    ``(m_l, n_l)``; the flattened problem shape is
    ``(prod m_l, prod n_l)`` with C-order (row-major) flattening of the
    grid axes, matching ``jnp.reshape``.
    """

    factors: tuple[jax.Array, ...]

    def __post_init__(self):
        if not self.factors:
            raise ValueError("GridGeometry needs at least one axis factor")
        object.__setattr__(self, "factors", tuple(self.factors))

    @property
    def grid_shape(self) -> tuple[tuple[int, int], ...]:
        return tuple(tuple(C.shape) for C in self.factors)

    @property
    def shape(self) -> tuple[int, int]:
        return (math.prod(C.shape[0] for C in self.factors),
                math.prod(C.shape[1] for C in self.factors))

    def cost(self) -> jax.Array:
        """Dense kron-sum mirror (tests / explicit-C parity)."""
        C = self.factors[0]
        for Cn in self.factors[1:]:
            C = (C[:, None, :, None] + Cn[None, :, None, :]).reshape(
                C.shape[0] * Cn.shape[0], C.shape[1] * Cn.shape[1])
        return C

    def kernel(self, reg: float) -> jax.Array:
        """Dense Kronecker mirror ``kron(exp(-C_l / reg))``."""
        K = jnp.exp(-self.factors[0] / reg)
        for Cn in self.factors[1:]:
            Kn = jnp.exp(-Cn / reg)
            K = (K[:, None, :, None] * Kn[None, :, None, :]).reshape(
                K.shape[0] * Kn.shape[0], K.shape[1] * Kn.shape[1])
        return K

    def _apply(self, vec, reg, *, transpose: bool) -> jax.Array:
        axis_in = 1 if not transpose else 0
        shp_in = tuple(C.shape[axis_in] for C in self.factors)
        V = vec.reshape(shp_in)
        for l, C in enumerate(self.factors):
            K = jnp.exp(-C / reg)
            if transpose:
                K = K.T
            # contract axis l of V against K's input axis, put the output
            # axis back in place — one small matmul per grid axis
            V = jnp.moveaxis(jnp.tensordot(K, V, axes=(1, l)), 0, l)
        return V.reshape(-1)

    def apply_kernel(self, v: jax.Array, reg: float) -> jax.Array:
        return self._apply(v, float(reg), transpose=False)

    def apply_kernel_T(self, u: jax.Array, reg: float) -> jax.Array:
        return self._apply(u, float(reg), transpose=True)

    def _apply_lse(self, z, reg, *, transpose: bool) -> jax.Array:
        axis_in = 1 if not transpose else 0
        shp_in = tuple(C.shape[axis_in] for C in self.factors)
        W = z.reshape(shp_in) / reg
        for l, C in enumerate(self.factors):
            A = -C / reg
            if transpose:
                A = A.T
            Wf = jnp.moveaxis(W, l, 0)          # (in_l, rest...)
            comb = A[(...,) + (None,) * (Wf.ndim - 1)] + Wf[None]
            W = jnp.moveaxis(logsumexp(comb, axis=1), 0, l)
        return W.reshape(-1)

    def apply_lse(self, z: jax.Array, reg: float) -> jax.Array:
        return self._apply_lse(z, float(reg), transpose=False)

    def apply_lse_T(self, z: jax.Array, reg: float) -> jax.Array:
        return self._apply_lse(z, float(reg), transpose=True)


jax.tree_util.register_dataclass(GridGeometry, data_fields=["factors"],
                                 meta_fields=[])
