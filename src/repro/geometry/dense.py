"""Explicit dense cost matrix — the degenerate Geometry backend.

Wraps today's precomputed ``C`` so every solver entry point can take a
``Geometry`` uniformly; semantics (and bytes moved) are exactly the
historical dense path.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.scipy.special import logsumexp

from repro.geometry.base import Geometry


@functools.partial(jax.jit, static_argnames=("reg",))
def _gibbs(C: jax.Array, *, reg: float) -> jax.Array:
    # evaluate the exp on a lane-aligned minor dim and slice back, so the
    # values match the implicit geometries' lane-padded tile evaluation
    # bitwise (scalar-tail vs SIMD exp round differently; see
    # pointcloud._lane_padded)
    N = C.shape[-1]
    pad = (-N) % 128
    if pad:
        C = jnp.pad(C, [(0, 0)] * (C.ndim - 1) + [(0, pad)])
    K = jnp.exp(-C / reg)
    if pad:
        # the barrier stops XLA from fusing the slice into the exp loop
        # and narrowing its bounds back to a tailed evaluation
        K = jax.lax.optimization_barrier(K)
    return K[..., :N]


@dataclasses.dataclass(frozen=True)
class DenseGeometry(Geometry):
    """Geometry backed by an explicit (M, N) (or (..., M, N)) cost matrix."""

    C: jax.Array

    @property
    def shape(self) -> tuple[int, int]:
        return tuple(self.C.shape[-2:])

    @property
    def batch_shape(self) -> tuple[int, ...]:
        return tuple(self.C.shape[:-2])

    def cost(self) -> jax.Array:
        return self.C

    def kernel(self, reg: float) -> jax.Array:
        return _gibbs(self.C, reg=float(reg))

    def apply_kernel(self, v: jax.Array, reg: float) -> jax.Array:
        return self.kernel(reg) @ v

    def apply_kernel_T(self, u: jax.Array, reg: float) -> jax.Array:
        return u @ self.kernel(reg)

    def apply_lse(self, z: jax.Array, reg: float) -> jax.Array:
        return logsumexp((z[None, :] - self.C) / reg, axis=1)

    def apply_lse_T(self, z: jax.Array, reg: float) -> jax.Array:
        return logsumexp((z[:, None] - self.C) / reg, axis=0)


jax.tree_util.register_dataclass(DenseGeometry, data_fields=["C"],
                                 meta_fields=[])
