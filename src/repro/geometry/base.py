"""Cost geometry abstraction: where the ground cost comes from.

Every solver in this repo consumes the ground cost ``C`` (or its Gibbs
kernel ``K = exp(-C / reg)``) somewhere: the matrix-scaling paths take
``K`` as the initial coupling, the u/v and log-domain paths apply ``K``
(or ``(z - C)/eps`` logsumexps) every iteration. Historically that meant a
dense, precomputed, HBM-resident ``M*N`` operand — even when the cost is a
*function* of ``O(M + N)`` data (point-cloud squared Euclidean, separable
grid costs). A ``Geometry`` names the cost *source* instead of its
materialization, so each consumer can pick the cheapest faithful
evaluation: load a dense tile, compute the tile on-chip from coordinates,
or contract small per-axis factors.

Three backends (see the sibling modules):

- ``DenseGeometry`` — today's explicit ``C``; semantics unchanged, the
  degenerate "the materialization IS the source" case.
- ``PointCloudGeometry`` — squared-Euclidean cost of ``(M, d)`` / ``(N, d)``
  coordinate clouds. ``is_implicit``: the Pallas kernel stack computes
  Gibbs-kernel tiles in VMEM straight from the coordinates, so no ``M*N``
  cost array ever exists in HBM on that path.
- ``GridGeometry`` — separable (kron-sum) cost over a product grid; kernel
  applications are per-axis contractions of small factors and never form
  ``M*N`` at all.

All geometries are registered pytrees, so they pass through ``jax.jit``
boundaries as arguments (array fields trace; float metadata is static).

Numerical contract: a geometry's materializing ``kernel()`` / ``cost()``
mirrors and its implicit tile evaluations round identically (asserted
bit-for-bit in fp32 by tests/test_geometry.py), so the solver tiers can
dispatch on memory layout without changing results. That is why the
implicit geometries precompute any shared reductions (e.g. squared norms)
once at construction: recomputing them inside different fusion contexts is
where bitwise reproducibility would die (XLA FMA-contracts a ``mul+add``
in one fusion and not another).
"""
from __future__ import annotations

import jax


class Geometry:
    """Abstract cost source for a (M, N) transport problem.

    Subclasses implement the materializing mirrors (``cost``, ``kernel``)
    and the lazy applications (``apply_kernel``, ``apply_kernel_T``,
    ``apply_lse``, ``apply_lse_T``); consumers pick by memory budget.
    ``is_implicit`` marks geometries whose kernel path computes cost tiles
    on-chip instead of loading them (the ops dispatcher uses it to shrink
    the VMEM tile budget to the coupling only — see ``ops.resident_fits``).
    """

    #: True when the Pallas kernel stack can compute this geometry's Gibbs
    #: tiles on-chip from O(M + N) operands instead of loading an M*N array.
    is_implicit: bool = False

    @property
    def shape(self) -> tuple[int, int]:
        """(M, N) of the cost this geometry describes (per problem; batched
        geometries report the trailing per-problem shape)."""
        raise NotImplementedError

    @property
    def batch_shape(self) -> tuple[int, ...]:
        """Leading batch dims ((,) for a single problem)."""
        return ()

    def cost(self) -> jax.Array:
        """Materialize the dense cost matrix C (tests / fallbacks)."""
        raise NotImplementedError

    def kernel(self, reg: float) -> jax.Array:
        """Materialize the Gibbs kernel ``K = exp(-C / reg)``.

        This is the *mirror* the dense solver tiers consume; implicit
        geometries compute it with exactly the arithmetic their on-chip
        tile evaluation uses, never via an intermediate dense ``C``.
        """
        raise NotImplementedError

    def apply_kernel(self, v: jax.Array, reg: float) -> jax.Array:
        """``K @ v`` without holding a dense K (the u/v solvers' matvec)."""
        raise NotImplementedError

    def apply_kernel_T(self, u: jax.Array, reg: float) -> jax.Array:
        """``K^T @ u`` without holding a dense K."""
        raise NotImplementedError

    def apply_lse(self, z: jax.Array, reg: float) -> jax.Array:
        """``logsumexp_j((z_j - C_ij) / reg)`` per row (log-domain solver)."""
        raise NotImplementedError

    def apply_lse_T(self, z: jax.Array, reg: float) -> jax.Array:
        """``logsumexp_i((z_i - C_ij) / reg)`` per column."""
        raise NotImplementedError
